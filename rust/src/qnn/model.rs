//! The full TinyCL workload in hardware numerics: quantized model state,
//! forward, backward and the fused update sequence the control unit runs.
//!
//! Two interchangeable compute engines execute the layer math
//! ([`QnnEngine`]): the naive per-element loops of [`layers`] (the
//! debugging oracle) and the integer im2col+GEMM fast path of
//! [`super::gemm`] — **bit-identical** by construction (wrapping 32-bit
//! accumulation is associative; see `qnn::gemm` and
//! `tests/qnn_fast_parity.rs`), so the default is the fast engine.
//!
//! **Batch-N semantics.** The paper trains at batch 1; [`QModel::train_batch`]
//! generalizes the control unit's sequence to a minibatch while keeping
//! every writeback the hardware's: all forwards and gradient
//! propagations run against the batch-entry parameters (one big GEMM
//! set on the fast engine), then the parameter updates — the fused
//! dense update and both kernel updates — are applied per sample in
//! stream order, each advancing the dither step counter exactly as a
//! sequence of batch-1 steps would. For `B = 1` this reduces bit-for-bit
//! to the paper's per-sample step (which is how [`QModel::train_step`]
//! is implemented), keeping the `sim` parity suites green.

use super::gemm as qgemm;
use super::layers;
use super::QnnEngine;
use crate::fixed::gemm::QPackedA;
use crate::fixed::Fx;
use crate::nn::gemm::{pack_batch, packed_to_rows, rows_to_packed};
use crate::nn::loss;
use crate::nn::ModelConfig;
use crate::tensor::{quantize_tensor, Shape, Tensor};
use std::cell::RefCell;

/// Quantized parameters (what Kernel memory holds).
#[derive(Clone, Debug)]
pub struct QParams {
    pub k1: Tensor<Fx>,
    pub k2: Tensor<Fx>,
    pub w: Tensor<Fx>,
}

impl QParams {
    /// Quantize float parameters into the Q4.12 domain.
    pub fn from_f32(p: &crate::nn::Params) -> QParams {
        QParams {
            k1: quantize_tensor(&p.k1),
            k2: quantize_tensor(&p.k2),
            w: quantize_tensor(&p.w),
        }
    }
}

/// Gradients materialized by the backward pass (dense dW is not here —
/// the hardware fuses it into the update, see `layers::dense_weight_update`).
#[derive(Clone, Debug)]
pub struct QGradients {
    pub k1: Tensor<Fx>,
    pub k2: Tensor<Fx>,
}

/// Forward activations the backward pass reuses (Partial Feature memory).
pub struct QForwardCache {
    pub x: Tensor<Fx>,
    pub a1: Tensor<Fx>,
    pub a2: Tensor<Fx>,
    pub logits: Vec<Fx>,
}

/// Caches from one fast-engine batched forward pass: channel-major
/// packed activations (`nn::gemm` layout; plain CHW for `B = 1`) plus
/// the im2col column matrices, kept so backward never re-packs.
struct FastForward {
    cols1: Vec<Fx>,
    a1: Vec<Fx>,
    cols2: Vec<Fx>,
    a2: Vec<Fx>,
    /// Sample-major post-ReLU dense input (B × dense_in) — `None` at
    /// `B = 1`, where the packed layout already *is* the single sample's
    /// flattened CHW row (no copy on the per-sample hot path).
    a2_rows: Option<Vec<Fx>>,
    /// Sample-major logits (B × num_classes).
    logits: Vec<Fx>,
}

impl FastForward {
    /// The dense layer's sample-major input rows.
    fn a2_rows(&self) -> &[Fx] {
        self.a2_rows.as_deref().unwrap_or(&self.a2)
    }
}

/// Conv kernels repacked into microkernel tile order
/// ([`crate::fixed::gemm::QPackedA`]) — built once per weight snapshot
/// ([`QModel::pack_weights`], called at `clone_replica` / barrier
/// re-broadcast), dropped by every weight update.
#[derive(Clone)]
struct QPackedWeights {
    k1: QPackedA,
    k2: QPackedA,
}

impl QPackedWeights {
    fn pack(params: &QParams) -> QPackedWeights {
        let d1 = params.k1.shape().dims();
        let d2 = params.k2.shape().dims();
        QPackedWeights {
            k1: QPackedA::pack(d1[0], d1[1] * d1[2] * d1[3], params.k1.data()),
            k2: QPackedA::pack(d2[0], d2[1] * d2[2] * d2[3], params.k2.data()),
        }
    }

    fn is_fresh(&self, params: &QParams) -> bool {
        let d1 = params.k1.shape().dims();
        let d2 = params.k2.shape().dims();
        self.k1.matches(d1[0], d1[1] * d1[2] * d1[3], params.k1.data())
            && self.k2.matches(d2[0], d2[1] * d2[2] * d2[3], params.k2.data())
    }
}

/// Pool of reusable Q4.12 scratch buffers for the fast engine's column
/// matrices and conv outputs; every consumer clears + resizes before
/// use, so recycling never changes bits.
#[derive(Clone, Default)]
struct QScratch {
    bufs: Vec<Vec<Fx>>,
}

impl QScratch {
    fn take(&mut self) -> Vec<Fx> {
        // Shares the f32 engine's scratch counters — both pools answer
        // the same question (is recycling working?).
        let (reuse, alloc) = crate::nn::model::scratch_obs();
        match self.bufs.pop() {
            Some(buf) => {
                reuse.inc();
                buf
            }
            None => {
                alloc.inc();
                Vec::new()
            }
        }
    }

    fn put(&mut self, mut buf: Vec<Fx>) {
        buf.clear();
        self.bufs.push(buf);
    }
}

/// Quantized model driving the six control-unit computations in the order
/// the paper's CU sequences them.
// Clone: replicated serving snapshots the model per replica and
// re-broadcasts it after each train barrier (`serve::server`); state is
// plain tensors + counters, so a clone is bit-identical by construction.
#[derive(Clone)]
pub struct QModel {
    pub config: ModelConfig,
    pub params: QParams,
    /// Train-step counter — keys the stochastic-rounding dither
    /// ([`crate::fixed::wb_dither`]); reset on (re)construction.
    pub step: u64,
    /// Compute engine for the layer math (default: the bit-identical
    /// integer GEMM fast path; `naive` is the debugging oracle).
    pub engine: QnnEngine,
    /// Worker threads for the fast engine's GEMMs (1 = serial). Thread
    /// count never changes results — disjoint-column sharding of
    /// order-independent wrapping sums (see `fixed::gemm`).
    pub threads: usize,
    /// Snapshot-packed conv kernels for the fast forward. `None` until
    /// [`QModel::pack_weights`]; dropped by every weight update.
    packed: Option<QPackedWeights>,
    /// Recycled fast-engine scratch buffers (interior-mutable so the
    /// `&self` forward paths can reuse them across calls).
    scratch: RefCell<QScratch>,
    /// Monotone weight-snapshot version, bumped by every weight update
    /// (the serving layer's diff re-broadcast key).
    version: u64,
    /// Per-tensor stamp (k1, k2, w): the `version` at each tensor's
    /// last update.
    tensor_versions: [u64; 3],
    /// Per-task dense heads (always ≥ 1). Same contract as the float
    /// model: the active head's live tensor is `params.w`, and
    /// `heads[active_task]` is a stale placeholder parked by the last
    /// head swap.
    heads: Vec<Tensor<Fx>>,
    /// Version stamp of each *parked* head (the active head's stamp
    /// lives in `tensor_versions[2]`).
    head_versions: Vec<u64>,
    /// Which head `params.w` currently is.
    active_task: usize,
    /// When set, training moves only the active dense head (the conv
    /// backbone stays frozen; a barrier diff then ships one head).
    freeze_backbone: bool,
}

/// Host-side loss layer (float; see module docs of `qnn`): loss, top-1
/// correctness and the re-quantized loss gradient for one sample.
fn loss_grad(logits: &[Fx], label: usize, active_classes: usize) -> (f32, bool, Vec<Fx>) {
    let f: Vec<f32> = logits.iter().map(|l| l.to_f32()).collect();
    let (loss_value, dl) = loss::softmax_ce(&f, label, active_classes);
    let correct = loss::predict(&f, active_classes) == label;
    (loss_value, correct, dl.iter().map(|&g| Fx::from_f32(g)).collect())
}

impl QModel {
    pub fn new(config: ModelConfig, params: QParams) -> QModel {
        let heads = vec![params.w.clone()];
        QModel {
            config,
            params,
            step: 0,
            engine: QnnEngine::default(),
            threads: 1,
            packed: None,
            scratch: RefCell::new(QScratch::default()),
            version: 0,
            tensor_versions: [0; 3],
            heads,
            head_versions: vec![0],
            active_task: 0,
            freeze_backbone: false,
        }
    }

    /// Record a weight update: drop the packed conv snapshot and
    /// advance the version stamps of the tensors that moved (see the
    /// float model's `touch` — same contract).
    fn touch(&mut self, k1: bool, k2: bool, w: bool) {
        self.packed = None;
        self.version += 1;
        let v = self.version;
        if k1 {
            self.tensor_versions[0] = v;
        }
        if k2 {
            self.tensor_versions[1] = v;
        }
        if w {
            self.tensor_versions[2] = v;
        }
    }

    /// Current weight-snapshot version (advances on every update).
    pub fn weights_version(&self) -> u64 {
        self.version
    }

    /// Keep the version counter monotone across a wholesale model
    /// replacement: GDumb re-init builds a brand-new `QModel` (version
    /// 0), but diff sync must still see every tensor as newer than any
    /// replica stamped from the old lineage. Adopt the predecessor's
    /// counter, then stamp all tensors as rewritten.
    pub fn inherit_version(&mut self, prev_version: u64) {
        self.version = prev_version;
        self.touch(true, true, true);
    }

    /// Bytes of one full Q4.12 weight snapshot (2 bytes per value):
    /// the shared conv backbone plus every task head.
    pub fn weights_bytes(&self) -> u64 {
        let head_values: usize = (0..self.heads.len()).map(|h| self.head_view(h).data().len()).sum();
        2 * (self.params.k1.data().len() + self.params.k2.data().len() + head_values) as u64
    }

    // ---- Multi-task heads -------------------------------------------
    //
    // Mirror of the float model's head machinery (`nn::model`): one
    // shared integer conv backbone, K quantized dense heads, O(1)
    // swap-in of the active head, per-head version stamps for the serve
    // layer's diff re-broadcast. A head is quantized from the *same*
    // deterministic float draw the reference model uses, so the two
    // engines' heads stay comparable sample-for-sample.

    /// Number of task heads (≥ 1).
    pub fn num_tasks(&self) -> usize {
        self.heads.len()
    }

    /// The task whose head is live in `params.w`.
    pub fn active_task(&self) -> usize {
        self.active_task
    }

    /// Output width of the *active* head, derived from the dense weight
    /// shape (heads may be narrower than `config.num_classes`).
    pub fn out_classes(&self) -> usize {
        self.params.w.shape().dims()[1]
    }

    /// Freeze (or thaw) the conv backbone: frozen, `train_batch` routes
    /// through the deepest-cut suffix step and moves only the active
    /// dense head.
    pub fn set_freeze_backbone(&mut self, freeze: bool) {
        self.freeze_backbone = freeze;
    }

    /// Whether the conv backbone is frozen.
    pub fn backbone_frozen(&self) -> bool {
        self.freeze_backbone
    }

    /// Add a fresh quantized dense head with `classes` outputs,
    /// deterministic in `seed` (the float draw of `nn::fresh_head`,
    /// quantized tensor-by-tensor like every other init). Returns the
    /// new task id; the active task is unchanged.
    pub fn add_task_head(&mut self, classes: usize, seed: u64) -> usize {
        let w = quantize_tensor(&crate::nn::fresh_head(&self.config, classes, seed));
        self.version += 1;
        self.head_versions.push(self.version);
        self.heads.push(w);
        self.heads.len() - 1
    }

    /// Make task `task`'s head the live `params.w` (O(1) swaps, no
    /// weight bytes move, the conv pack survives, the version does not
    /// advance). Errors actionably when the head does not exist.
    pub fn set_active_task(&mut self, task: usize) -> Result<(), String> {
        if task >= self.heads.len() {
            return Err(format!(
                "task {task} has no head: model has {} head(s) (ids 0..={}); \
                 call add_task_head before routing task {task}",
                self.heads.len(),
                self.heads.len() - 1
            ));
        }
        if task == self.active_task {
            return Ok(());
        }
        let old = self.active_task;
        std::mem::swap(&mut self.heads[old], &mut self.params.w);
        self.head_versions[old] = self.tensor_versions[2];
        std::mem::swap(&mut self.heads[task], &mut self.params.w);
        self.tensor_versions[2] = self.head_versions[task];
        self.active_task = task;
        Ok(())
    }

    /// Current weights of head `task` — the live `params.w` when
    /// active, the parked copy otherwise.
    pub fn head_view(&self, task: usize) -> &Tensor<Fx> {
        assert!(
            task < self.heads.len(),
            "task {task} has no head: model has {} head(s)",
            self.heads.len()
        );
        if task == self.active_task {
            &self.params.w
        } else {
            &self.heads[task]
        }
    }

    /// Version stamp of head `task`'s current weights.
    fn head_stamp(&self, task: usize) -> u64 {
        if task == self.active_task {
            self.tensor_versions[2]
        } else {
            self.head_versions[task]
        }
    }

    /// Bytes of head `task` — the entire per-task parameter growth.
    pub fn head_bytes(&self, task: usize) -> u64 {
        2 * self.head_view(task).data().len() as u64
    }

    /// Adopt `src`'s weights by diff: copy exactly the tensors whose
    /// version stamp differs plus the train-step dither counter, adopt
    /// `src`'s stamps, and return the bytes copied. The dither counter
    /// must travel with every diff — any replica may lead a future
    /// barrier, and stochastic-rounding bits key on it (`wb_dither`),
    /// so bit-exact pool parity requires it synced even when only the
    /// dense head moved. A dense-only diff keeps this model's conv
    /// weight pack valid (`QPackedWeights` holds only k1/k2).
    pub fn sync_weights_from(&mut self, src: &QModel) -> u64 {
        let mut bytes = 0u64;
        // Heads added on the source since this replica's snapshot.
        while self.heads.len() < src.heads.len() {
            let h = self.heads.len();
            self.heads.push(src.head_view(h).clone());
            self.head_versions.push(src.head_stamp(h));
            bytes += 2 * self.heads[h].data().len() as u64;
        }
        // Align the active head (a local swap — no weight bytes move);
        // the tensor loop below then diffs `w` by stamp as usual.
        if self.active_task != src.active_task {
            self.set_active_task(src.active_task).expect("heads grown above");
        }
        // A source with *fewer* heads (a reinit resets to one) wins.
        if self.heads.len() > src.heads.len() {
            self.heads.truncate(src.heads.len());
            self.head_versions.truncate(src.heads.len());
        }
        // Parked heads whose stamp advanced on the source.
        for h in 0..self.heads.len() {
            if h == self.active_task || self.head_versions[h] == src.head_stamp(h) {
                continue;
            }
            self.heads[h] = src.head_view(h).clone();
            self.head_versions[h] = src.head_stamp(h);
            bytes += 2 * self.heads[h].data().len() as u64;
        }
        let mut conv_changed = false;
        for i in 0..3 {
            if self.tensor_versions[i] == src.tensor_versions[i] {
                continue;
            }
            let (dst_t, src_t) = match i {
                0 => (&mut self.params.k1, &src.params.k1),
                1 => (&mut self.params.k2, &src.params.k2),
                _ => (&mut self.params.w, &src.params.w),
            };
            *dst_t = src_t.clone();
            bytes += 2 * dst_t.data().len() as u64;
            self.tensor_versions[i] = src.tensor_versions[i];
            conv_changed |= i < 2;
        }
        self.version = src.version;
        self.step = src.step;
        if conv_changed {
            self.packed = src.packed.clone();
        }
        bytes
    }

    /// Repack the conv kernels into microkernel tile order for the fast
    /// forward. Called once per weight snapshot (`clone_replica` /
    /// barrier re-broadcast); every weight update drops the pack, and a
    /// debug assertion on the forward catches any update site that
    /// forgets. Packing never changes bits — wrapping adds are
    /// order-independent, and the packed kernels are the same values in
    /// tile order (`fixed::gemm`).
    pub fn pack_weights(&mut self) {
        self.packed = Some(QPackedWeights::pack(&self.params));
    }

    /// From a float model (shared init path with the reference).
    pub fn from_model(m: &crate::nn::Model) -> QModel {
        QModel::new(m.config.clone(), QParams::from_f32(&m.params))
    }

    /// Select the compute engine (builder-style; parameters untouched).
    pub fn with_engine(mut self, engine: QnnEngine) -> QModel {
        self.engine = engine;
        self
    }

    /// Set the GEMM worker-thread budget (builder-style; clamped to ≥1).
    pub fn with_threads(mut self, threads: usize) -> QModel {
        self.threads = threads.max(1);
        self
    }

    /// Fast-engine batched forward: pack once, one integer GEMM per
    /// layer pass. Bit-identical per sample to the naive forward.
    fn fast_forward(&self, xs: &[&Tensor<Fx>]) -> FastForward {
        let b = xs.len();
        let hw = self.config.image_size;
        let n = hw * hw;
        let cin = self.config.in_channels;
        let cc = self.config.conv_channels;
        let t = self.threads;
        assert_eq!(
            xs[0].shape(),
            &Shape::d3(cin, hw, hw),
            "input must match the model geometry"
        );
        // Kernels come from the packed snapshot when one exists (serving
        // replicas); a model trained between forwards packs on the fly —
        // the kernels are tiny, so the repack is negligible next to the
        // GEMMs.
        let packed_store;
        let pw: &QPackedWeights = match &self.packed {
            Some(p) => {
                debug_assert!(
                    p.is_fresh(&self.params),
                    "stale packed weights: a weight update failed to invalidate the pack"
                );
                crate::nn::model::pack_obs().0.inc();
                p
            }
            None => {
                crate::nn::model::pack_obs().1.inc();
                packed_store = QPackedWeights::pack(&self.params);
                &packed_store
            }
        };
        // For B = 1 the packed layout *is* CHW — borrow instead of copy.
        let packed_input;
        let x0: &[Fx] = if b == 1 {
            xs[0].data()
        } else {
            packed_input = pack_batch(xs);
            &packed_input
        };
        let mut cols1 = self.scratch.borrow_mut().take();
        let (oh, ow) = qgemm::im2col_batch_into(x0, b, cin, hw, hw, 3, 3, 1, t, &mut cols1);
        debug_assert_eq!((oh, ow), (hw, hw), "3×3 s1 p1 conv preserves geometry");
        let mut a1 = self.scratch.borrow_mut().take();
        qgemm::conv_forward_batch_packed_into(&cols1, &pw.k1, b * n, true, &mut a1, t);
        let mut cols2 = self.scratch.borrow_mut().take();
        qgemm::im2col_batch_into(&a1, b, cc, hw, hw, 3, 3, 1, t, &mut cols2);
        let mut a2 = self.scratch.borrow_mut().take();
        qgemm::conv_forward_batch_packed_into(&cols2, &pw.k2, b * n, true, &mut a2, t);
        let a2_rows = if b == 1 { None } else { Some(packed_to_rows(&a2, cc, b, n)) };
        let logits = qgemm::dense_forward_batch(
            a2_rows.as_deref().unwrap_or(&a2),
            &self.params.w,
            b,
            t,
        );
        FastForward { cols1, a1, cols2, a2, a2_rows, logits }
    }

    /// Return a consumed [`FastForward`]'s large buffers to the scratch
    /// pool for the next call.
    fn recycle(&self, fwd: FastForward) {
        let mut sc = self.scratch.borrow_mut();
        sc.put(fwd.cols1);
        sc.put(fwd.a1);
        sc.put(fwd.cols2);
        sc.put(fwd.a2);
    }

    /// Forward pass (computations 1, 1, 4 of §III-F) with fused ReLU,
    /// keeping the activations backward reuses.
    pub fn forward_cached(&self, x: &Tensor<Fx>) -> QForwardCache {
        match self.engine {
            QnnEngine::Naive => {
                let a1 = layers::conv_forward(x, &self.params.k1, 1, true);
                let a2 = layers::conv_forward(&a1, &self.params.k2, 1, true);
                let logits = layers::dense_forward(a2.data(), &self.params.w);
                QForwardCache { x: x.clone(), a1, a2, logits }
            }
            QnnEngine::Fast => {
                let hw = self.config.image_size;
                let cc = self.config.conv_channels;
                let f = self.fast_forward(&[x]);
                QForwardCache {
                    x: x.clone(),
                    a1: Tensor::from_vec(Shape::d3(cc, hw, hw), f.a1),
                    a2: Tensor::from_vec(Shape::d3(cc, hw, hw), f.a2),
                    logits: f.logits,
                }
            }
        }
    }

    pub fn forward(&self, x: &Tensor<Fx>) -> Vec<Fx> {
        match self.engine {
            QnnEngine::Naive => self.forward_cached(x).logits,
            QnnEngine::Fast => {
                let mut fwd = self.fast_forward(&[x]);
                let logits = std::mem::take(&mut fwd.logits);
                self.recycle(fwd);
                logits
            }
        }
    }

    /// Batched inference: per-sample logits. The fast engine runs the
    /// whole batch as packed integer GEMMs; the naive engine loops.
    pub fn forward_batch(&self, xs: &[&Tensor<Fx>]) -> Vec<Vec<Fx>> {
        assert!(!xs.is_empty(), "empty batch");
        match self.engine {
            QnnEngine::Naive => xs.iter().map(|x| self.forward(x)).collect(),
            QnnEngine::Fast => {
                let classes = self.out_classes();
                let fwd = self.fast_forward(xs);
                let out = fwd.logits.chunks(classes).map(|c| c.to_vec()).collect();
                self.recycle(fwd);
                out
            }
        }
    }

    /// Predicted class over the active head.
    pub fn predict(&self, x: &Tensor<Fx>, active_classes: usize) -> usize {
        let logits = self.forward(x);
        let f: Vec<f32> = logits.iter().map(|l| l.to_f32()).collect();
        loss::predict(&f, active_classes)
    }

    /// Batched prediction over the active head (one packed forward on
    /// the fast engine — bit-identical to per-sample `predict`).
    pub fn predict_batch(&self, xs: &[&Tensor<Fx>], active_classes: usize) -> Vec<usize> {
        self.forward_batch(xs)
            .iter()
            .map(|logits| {
                let f: Vec<f32> = logits.iter().map(|l| l.to_f32()).collect();
                loss::predict(&f, active_classes)
            })
            .collect()
    }

    /// Dense forward against an arbitrary head's weights (engine seam).
    /// Both engines are bit-identical per sample (wrapping adds are
    /// order-independent), so routing through either is exact.
    fn dense_forward_with(&self, flat: &[Fx], w: &Tensor<Fx>) -> Vec<Fx> {
        match self.engine {
            QnnEngine::Naive => layers::dense_forward(flat, w),
            QnnEngine::Fast => qgemm::dense_forward_batch(flat, w, 1, self.threads),
        }
    }

    /// Batched inference over a *mixed-task* batch: one shared integer
    /// backbone pass, then each sample's logits from its own task head.
    /// Per sample this is bit-identical to the single-task forward on
    /// both engines (integer wrapping sums are order-independent; the
    /// non-packed cut-point convs match the packed serve convs
    /// bit-for-bit).
    pub fn forward_batch_tasks(&self, xs: &[&Tensor<Fx>], tasks: &[usize]) -> Vec<Vec<Fx>> {
        assert!(!xs.is_empty(), "empty batch");
        assert_eq!(xs.len(), tasks.len(), "batch inputs vs tasks");
        let acts = self.forward_to_cut_batch(xs, crate::nn::MAX_CUT);
        acts.iter()
            .zip(tasks)
            .map(|(a, &t)| self.dense_forward_with(a.data(), self.head_view(t)))
            .collect()
    }

    /// Predicted classes for a mixed-task batch, each sample masked to
    /// the first `actives[i]` outputs of its own head.
    pub fn predict_batch_tasks(
        &self,
        xs: &[&Tensor<Fx>],
        tasks: &[usize],
        actives: &[usize],
    ) -> Vec<usize> {
        assert_eq!(xs.len(), actives.len(), "batch inputs vs active masks");
        self.forward_batch_tasks(xs, tasks)
            .iter()
            .zip(actives)
            .map(|(logits, &active)| {
                let f: Vec<f32> = logits.iter().map(|l| l.to_f32()).collect();
                loss::predict(&f, active)
            })
            .collect()
    }

    /// One full train step exactly as the CU sequences it:
    /// forward → host loss grad → dense fused-update + grad-prop →
    /// conv2 kernel-grad + grad-prop → conv1 kernel-grad → kernel updates.
    ///
    /// Returns (loss, correct) computed at the host. Implemented as a
    /// `B = 1` [`QModel::train_batch`] (bit-identical by construction).
    pub fn train_step(
        &mut self,
        x: &Tensor<Fx>,
        label: usize,
        active_classes: usize,
        lr: Fx,
    ) -> (f32, bool) {
        let (loss_value, correct) = self.train_batch(&[x], &[label], active_classes, lr);
        (loss_value, correct == 1)
    }

    /// One minibatch train step: gradients against the batch-entry
    /// parameters, updates applied per sample in stream order (see the
    /// module docs). Returns (mean loss, correct count).
    pub fn train_batch(
        &mut self,
        xs: &[&Tensor<Fx>],
        labels: &[usize],
        active_classes: usize,
        lr: Fx,
    ) -> (f32, usize) {
        assert!(!xs.is_empty(), "empty batch");
        assert_eq!(xs.len(), labels.len(), "batch inputs vs labels");
        if self.freeze_backbone {
            // Frozen backbone: forward the conv prefix, then run the
            // dense-only suffix step (per-sample stream-order fused
            // updates, dither steps advancing exactly as a full step's
            // dense updates would) — only the active head moves.
            let acts = self.forward_to_cut_batch(xs, crate::nn::MAX_CUT);
            let act_refs: Vec<&Tensor<Fx>> = acts.iter().collect();
            return self.train_batch_from(crate::nn::MAX_CUT, &act_refs, labels, active_classes, lr);
        }
        self.touch(true, true, true); // the step below updates every parameter
        match self.engine {
            QnnEngine::Naive => self.train_batch_naive(xs, labels, active_classes, lr),
            QnnEngine::Fast => self.train_batch_fast(xs, labels, active_classes, lr),
        }
    }

    /// Naive-engine minibatch: the per-element reference loops in the
    /// exact sequence the fast engine must reproduce — the bit-exactness
    /// oracle for `tests/qnn_fast_parity.rs`.
    fn train_batch_naive(
        &mut self,
        xs: &[&Tensor<Fx>],
        labels: &[usize],
        active_classes: usize,
        lr: Fx,
    ) -> (f32, usize) {
        let b = xs.len();
        // 1. All forwards at the batch-entry parameters.
        let caches: Vec<QForwardCache> = xs.iter().map(|x| self.forward_cached(x)).collect();
        // 2. Host-side loss layer per sample.
        let mut loss_sum = 0.0f32;
        let mut correct = 0usize;
        let mut dys: Vec<Vec<Fx>> = Vec::with_capacity(b);
        for (cache, &label) in caches.iter().zip(labels) {
            let (l, c, dy) = loss_grad(&cache.logits, label, active_classes);
            loss_sum += l;
            correct += usize::from(c);
            dys.push(dy);
        }
        // 3. Dense gradient propagation (Eq. 5) for every sample at the
        // batch-entry weights (pre-update W, as in the batch-1 step).
        let da2s: Vec<Tensor<Fx>> = caches
            .iter()
            .zip(&dys)
            .map(|(cache, dy)| {
                Tensor::from_vec(
                    cache.a2.shape().clone(),
                    layers::dense_input_grad(dy, &self.params.w),
                )
            })
            .collect();
        // 4. Fused dense weight updates (Eq. 6 + SGD), per sample in
        // stream order — each reads the weights the previous wrote.
        let dshift = self.config.dense_grad_shift();
        for (i, (cache, dy)) in caches.iter().zip(&dys).enumerate() {
            let dy_scaled = layers::scale_grad(dy, lr);
            layers::dense_weight_update(
                &mut self.params.w,
                cache.a2.data(),
                &dy_scaled,
                dshift,
                self.step + i as u64,
            );
        }
        // 5. Conv backward per sample at the batch-entry kernels and the
        // cached activations (kernels update only after the batch).
        let shift = self.config.kgrad_shift();
        let mut dk2s = Vec::with_capacity(b);
        let mut dk1s = Vec::with_capacity(b);
        for (cache, da2) in caches.iter().zip(&da2s) {
            let dz2 = layers::relu_backward(da2, &cache.a2);
            dk2s.push(layers::conv_kernel_grad(&dz2, &cache.a1, self.params.k2.shape(), 1, shift));
            let da1 = layers::conv_input_grad(&dz2, &self.params.k2, cache.a1.shape(), 1);
            let dz1 = layers::relu_backward(&da1, &cache.a1);
            dk1s.push(layers::conv_kernel_grad(&dz1, &cache.x, self.params.k1.shape(), 1, shift));
        }
        // 6. Kernel updates per sample in stream order (dithered
        // writebacks, disjoint key streams, per-sample step counter).
        for (i, (dk2, dk1)) in dk2s.iter().zip(&dk1s).enumerate() {
            let s = self.step + i as u64;
            layers::param_update(&mut self.params.k2, dk2, lr, layers::DITHER_BASE_K2, s);
            layers::param_update(&mut self.params.k1, dk1, lr, layers::DITHER_BASE_K1, s);
        }
        self.step += b as u64;
        (loss_sum / b as f32, correct)
    }

    /// Fast-engine minibatch: the same sequence with each layer pass one
    /// packed integer GEMM, backward reusing the forward's im2col
    /// columns. Bit-identical to [`QModel::train_batch_naive`].
    fn train_batch_fast(
        &mut self,
        xs: &[&Tensor<Fx>],
        labels: &[usize],
        active_classes: usize,
        lr: Fx,
    ) -> (f32, usize) {
        let b = xs.len();
        let hw = self.config.image_size;
        let n = hw * hw;
        let cc = self.config.conv_channels;
        let classes = self.out_classes();
        let d_in = self.config.dense_in();
        let t = self.threads;
        let fwd = self.fast_forward(xs);
        // Host-side loss layer per sample.
        let mut loss_sum = 0.0f32;
        let mut correct = 0usize;
        let mut dy_rows: Vec<Fx> = Vec::with_capacity(b * classes);
        for (bi, &label) in labels.iter().enumerate() {
            let row = &fwd.logits[bi * classes..(bi + 1) * classes];
            let (l, c, dy) = loss_grad(row, label, active_classes);
            loss_sum += l;
            correct += usize::from(c);
            dy_rows.extend(dy);
        }
        // Dense gradient propagation (batched) at the batch-entry weights.
        let da2_rows = qgemm::dense_input_grad_batch(&dy_rows, &self.params.w, b, t);
        let da2 = if b == 1 { da2_rows } else { rows_to_packed(&da2_rows, cc, b, n) };
        // Fused dense updates per sample in stream order.
        let dshift = self.config.dense_grad_shift();
        let a2_rows = fwd.a2_rows();
        for bi in 0..b {
            let dy_b = &dy_rows[bi * classes..(bi + 1) * classes];
            let dy_scaled = layers::scale_grad(dy_b, lr);
            let x_b = &a2_rows[bi * d_in..(bi + 1) * d_in];
            qgemm::dense_weight_update(
                &mut self.params.w,
                x_b,
                &dy_scaled,
                dshift,
                self.step + bi as u64,
                t,
            );
        }
        // Conv backward, reusing the forward's column matrices.
        let shift = self.config.kgrad_shift();
        let dz2 = qgemm::relu_mask(&da2, &fwd.a2);
        let dk2s =
            qgemm::conv_kernel_grad_batch(&dz2, &fwd.cols2, self.params.k2.shape(), b, n, shift, t);
        let da1 = qgemm::conv_input_grad_batch(&dz2, &self.params.k2, b, hw, hw, hw, hw, 1, t);
        let dz1 = qgemm::relu_mask(&da1, &fwd.a1);
        let dk1s =
            qgemm::conv_kernel_grad_batch(&dz1, &fwd.cols1, self.params.k1.shape(), b, n, shift, t);
        // Kernel updates per sample in stream order.
        for (bi, (dk2, dk1)) in dk2s.iter().zip(&dk1s).enumerate() {
            let s = self.step + bi as u64;
            layers::param_update(&mut self.params.k2, dk2, lr, layers::DITHER_BASE_K2, s);
            layers::param_update(&mut self.params.k1, dk1, lr, layers::DITHER_BASE_K1, s);
        }
        self.recycle(fwd);
        self.step += b as u64;
        (loss_sum / b as f32, correct)
    }

    // ---- Cut-point datapath (latent replay) -------------------------
    //
    // Same split as `nn::Model`: frozen prefix forward to the cut,
    // suffix-only training from stored Q4.12 activations, with the CU's
    // per-sample stream-order writebacks and dither-step accounting
    // preserved exactly. Because the k2/w update sequence never consumes
    // a layer-1 gradient, the cut-1 suffix step's k2/w bits match the
    // full step's, and cut 0 delegates outright — bit-identical to raw
    // replay by construction.

    /// Forward the frozen prefix to `cut` for a whole batch (fused-ReLU
    /// integer convs; one packed GEMM set on the fast engine). Cut 0
    /// returns the inputs unchanged.
    pub fn forward_to_cut_batch(&self, xs: &[&Tensor<Fx>], cut: usize) -> Vec<Tensor<Fx>> {
        let max = crate::nn::MAX_CUT;
        assert!(cut <= max, "cut {cut} out of range (max {max})");
        assert!(!xs.is_empty(), "empty batch");
        if cut == 0 {
            return xs.iter().map(|x| (*x).clone()).collect();
        }
        let hw = self.config.image_size;
        let cc = self.config.conv_channels;
        match self.engine {
            QnnEngine::Naive => xs
                .iter()
                .map(|x| {
                    let a1 = layers::conv_forward(x, &self.params.k1, 1, true);
                    if cut == 1 {
                        a1
                    } else {
                        layers::conv_forward(&a1, &self.params.k2, 1, true)
                    }
                })
                .collect(),
            QnnEngine::Fast => {
                let b = xs.len();
                let n = hw * hw;
                let cin = self.config.in_channels;
                let t = self.threads;
                let packed_input;
                let x0: &[Fx] = if b == 1 {
                    xs[0].data()
                } else {
                    packed_input = pack_batch(xs);
                    &packed_input
                };
                let (cols1, _, _) = qgemm::im2col_batch(x0, b, cin, hw, hw, 3, 3, 1, t);
                let mut a = qgemm::conv_forward_batch(&cols1, &self.params.k1, b * n, true, t);
                if cut == 2 {
                    let (cols2, _, _) = qgemm::im2col_batch(&a, b, cc, hw, hw, 3, 3, 1, t);
                    a = qgemm::conv_forward_batch(&cols2, &self.params.k2, b * n, true, t);
                }
                let rows = if b == 1 { a } else { packed_to_rows(&a, cc, b, n) };
                rows.chunks(cc * n)
                    .map(|r| Tensor::from_vec(Shape::d3(cc, hw, hw), r.to_vec()))
                    .collect()
            }
        }
    }

    /// One suffix minibatch from stored activations at `cut`, with the
    /// control unit's per-sample stream-order writebacks (each advancing
    /// the dither step). At cut 0 this *is* [`QModel::train_batch`].
    /// Returns (mean loss, correct count).
    pub fn train_batch_from(
        &mut self,
        cut: usize,
        acts: &[&Tensor<Fx>],
        labels: &[usize],
        active_classes: usize,
        lr: Fx,
    ) -> (f32, usize) {
        let max = crate::nn::MAX_CUT;
        assert!(cut <= max, "cut {cut} out of range (max {max})");
        if cut == 0 {
            return self.train_batch(acts, labels, active_classes, lr);
        }
        assert!(!acts.is_empty(), "empty batch");
        assert_eq!(acts.len(), labels.len(), "batch inputs vs labels");
        // Suffix steps update weights too: cut 1 moves k2 + w, cut 2
        // moves only the dense head (the cheap-diff re-broadcast case).
        self.touch(false, cut == 1, true);
        if cut == 1 {
            match self.engine {
                QnnEngine::Naive => self.train_suffix_naive(acts, labels, active_classes, lr),
                QnnEngine::Fast => self.train_suffix_fast(acts, labels, active_classes, lr),
            }
        } else {
            self.train_dense_only(acts, labels, active_classes, lr)
        }
    }

    /// Cut-1 suffix minibatch, naive engine: conv2 + dense slice of
    /// [`QModel::train_batch_naive`]'s sequence (layer 1 is frozen and
    /// its gradients are never formed).
    fn train_suffix_naive(
        &mut self,
        acts: &[&Tensor<Fx>],
        labels: &[usize],
        active_classes: usize,
        lr: Fx,
    ) -> (f32, usize) {
        let b = acts.len();
        // Forwards from the stored a1, at the batch-entry parameters.
        let a2s: Vec<Tensor<Fx>> = acts
            .iter()
            .map(|a1| layers::conv_forward(a1, &self.params.k2, 1, true))
            .collect();
        let logits: Vec<Vec<Fx>> =
            a2s.iter().map(|a2| layers::dense_forward(a2.data(), &self.params.w)).collect();
        let mut loss_sum = 0.0f32;
        let mut correct = 0usize;
        let mut dys: Vec<Vec<Fx>> = Vec::with_capacity(b);
        for (lg, &label) in logits.iter().zip(labels) {
            let (l, c, dy) = loss_grad(lg, label, active_classes);
            loss_sum += l;
            correct += usize::from(c);
            dys.push(dy);
        }
        // Dense gradient propagation at the batch-entry weights.
        let da2s: Vec<Tensor<Fx>> = a2s
            .iter()
            .zip(&dys)
            .map(|(a2, dy)| {
                Tensor::from_vec(
                    a2.shape().clone(),
                    layers::dense_input_grad(dy, &self.params.w),
                )
            })
            .collect();
        // Fused dense updates per sample in stream order.
        let dshift = self.config.dense_grad_shift();
        for (i, (a2, dy)) in a2s.iter().zip(&dys).enumerate() {
            let dy_scaled = layers::scale_grad(dy, lr);
            layers::dense_weight_update(
                &mut self.params.w,
                a2.data(),
                &dy_scaled,
                dshift,
                self.step + i as u64,
            );
        }
        // Conv2 kernel gradients from the stored a1 (no layer-1 work).
        let shift = self.config.kgrad_shift();
        let mut dk2s = Vec::with_capacity(b);
        for ((a1, a2), da2) in acts.iter().zip(&a2s).zip(&da2s) {
            let dz2 = layers::relu_backward(da2, a2);
            dk2s.push(layers::conv_kernel_grad(&dz2, a1, self.params.k2.shape(), 1, shift));
        }
        for (i, dk2) in dk2s.iter().enumerate() {
            let s = self.step + i as u64;
            layers::param_update(&mut self.params.k2, dk2, lr, layers::DITHER_BASE_K2, s);
        }
        self.step += b as u64;
        (loss_sum / b as f32, correct)
    }

    /// Cut-1 suffix minibatch, fast engine: the packed-GEMM slice of
    /// [`QModel::train_batch_fast`]. Bit-identical to the naive suffix.
    fn train_suffix_fast(
        &mut self,
        acts: &[&Tensor<Fx>],
        labels: &[usize],
        active_classes: usize,
        lr: Fx,
    ) -> (f32, usize) {
        let b = acts.len();
        let hw = self.config.image_size;
        let n = hw * hw;
        let cc = self.config.conv_channels;
        let classes = self.out_classes();
        let d_in = self.config.dense_in();
        let t = self.threads;
        let packed_acts;
        let a1: &[Fx] = if b == 1 {
            acts[0].data()
        } else {
            packed_acts = pack_batch(acts);
            &packed_acts
        };
        let (cols2, _, _) = qgemm::im2col_batch(a1, b, cc, hw, hw, 3, 3, 1, t);
        let a2 = qgemm::conv_forward_batch(&cols2, &self.params.k2, b * n, true, t);
        let a2_rows_owned;
        let a2_rows: &[Fx] = if b == 1 {
            &a2
        } else {
            a2_rows_owned = packed_to_rows(&a2, cc, b, n);
            &a2_rows_owned
        };
        let logits = qgemm::dense_forward_batch(a2_rows, &self.params.w, b, t);
        let mut loss_sum = 0.0f32;
        let mut correct = 0usize;
        let mut dy_rows: Vec<Fx> = Vec::with_capacity(b * classes);
        for (bi, &label) in labels.iter().enumerate() {
            let row = &logits[bi * classes..(bi + 1) * classes];
            let (l, c, dy) = loss_grad(row, label, active_classes);
            loss_sum += l;
            correct += usize::from(c);
            dy_rows.extend(dy);
        }
        let da2_rows = qgemm::dense_input_grad_batch(&dy_rows, &self.params.w, b, t);
        let da2 = if b == 1 { da2_rows } else { rows_to_packed(&da2_rows, cc, b, n) };
        let dshift = self.config.dense_grad_shift();
        for bi in 0..b {
            let dy_b = &dy_rows[bi * classes..(bi + 1) * classes];
            let dy_scaled = layers::scale_grad(dy_b, lr);
            let x_b = &a2_rows[bi * d_in..(bi + 1) * d_in];
            qgemm::dense_weight_update(
                &mut self.params.w,
                x_b,
                &dy_scaled,
                dshift,
                self.step + bi as u64,
                t,
            );
        }
        let shift = self.config.kgrad_shift();
        let dz2 = qgemm::relu_mask(&da2, &a2);
        let dk2s =
            qgemm::conv_kernel_grad_batch(&dz2, &cols2, self.params.k2.shape(), b, n, shift, t);
        for (bi, dk2) in dk2s.iter().enumerate() {
            let s = self.step + bi as u64;
            layers::param_update(&mut self.params.k2, dk2, lr, layers::DITHER_BASE_K2, s);
        }
        self.step += b as u64;
        (loss_sum / b as f32, correct)
    }

    /// Cut-2 minibatch: the dense head is the whole trainable suffix.
    /// All logits are computed at the batch-entry weights, then the
    /// fused dense updates run per sample in stream order.
    fn train_dense_only(
        &mut self,
        acts: &[&Tensor<Fx>],
        labels: &[usize],
        active_classes: usize,
        lr: Fx,
    ) -> (f32, usize) {
        let b = acts.len();
        let d_in = self.config.dense_in();
        let dshift = self.config.dense_grad_shift();
        let mut loss_sum = 0.0f32;
        let mut correct = 0usize;
        match self.engine {
            QnnEngine::Naive => {
                let logits: Vec<Vec<Fx>> = acts
                    .iter()
                    .map(|a2| layers::dense_forward(a2.data(), &self.params.w))
                    .collect();
                for (i, (a2, &label)) in acts.iter().zip(labels).enumerate() {
                    let (l, c, dy) = loss_grad(&logits[i], label, active_classes);
                    loss_sum += l;
                    correct += usize::from(c);
                    let dy_scaled = layers::scale_grad(&dy, lr);
                    layers::dense_weight_update(
                        &mut self.params.w,
                        a2.data(),
                        &dy_scaled,
                        dshift,
                        self.step + i as u64,
                    );
                }
            }
            QnnEngine::Fast => {
                let t = self.threads;
                let classes = self.out_classes();
                let xd = crate::nn::gemm::rows_from_samples(acts);
                let logits = qgemm::dense_forward_batch(&xd, &self.params.w, b, t);
                for (bi, &label) in labels.iter().enumerate() {
                    let row = &logits[bi * classes..(bi + 1) * classes];
                    let (l, c, dy) = loss_grad(row, label, active_classes);
                    loss_sum += l;
                    correct += usize::from(c);
                    let dy_scaled = layers::scale_grad(&dy, lr);
                    let x_b = &xd[bi * d_in..(bi + 1) * d_in];
                    qgemm::dense_weight_update(
                        &mut self.params.w,
                        x_b,
                        &dy_scaled,
                        dshift,
                        self.step + bi as u64,
                        t,
                    );
                }
            }
        }
        self.step += b as u64;
        (loss_sum / b as f32, correct)
    }

    /// Re-initialize only the parameters at and after `cut`, resetting
    /// the dither step counter (as any reinit does) and leaving the
    /// frozen prefix's bits untouched. `reinit_suffix(0, s)` matches the
    /// coordinator's full reinit bit-for-bit (shared float init path,
    /// quantized tensor by tensor).
    pub fn reinit_suffix(&mut self, cut: usize, seed: u64) {
        let max = crate::nn::MAX_CUT;
        assert!(cut <= max, "cut {cut} out of range (max {max})");
        self.touch(cut == 0, cut <= 1, true);
        let fresh = QParams::from_f32(&crate::nn::Model::new(self.config.clone(), seed).params);
        if cut == 0 {
            self.params.k1 = fresh.k1;
        }
        if cut <= 1 {
            self.params.k2 = fresh.k2;
        }
        self.params.w = fresh.w;
        self.step = 0;
    }

    /// Input geometry helper.
    pub fn input_shape(&self) -> Shape {
        Shape::d3(
            self.config.in_channels,
            self.config.image_size,
            self.config.image_size,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Model, ModelConfig};
    use crate::tensor::quantize_tensor;
    use crate::util::rng::Pcg32;

    fn tiny() -> ModelConfig {
        ModelConfig {
            in_channels: 3,
            image_size: 8,
            conv_channels: 4,
            num_classes: 4,
            grad_clip: f32::INFINITY,
        }
    }

    fn rand_image(seed: u64, cfg: &ModelConfig) -> Tensor<f32> {
        let mut rng = Pcg32::seeded(seed);
        let shape = Shape::d3(cfg.in_channels, cfg.image_size, cfg.image_size);
        let n = shape.numel();
        Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    #[test]
    fn quantized_forward_tracks_float() {
        let cfg = tiny();
        let m = Model::new(cfg.clone(), 31);
        let qm = QModel::from_model(&m);
        let xf = rand_image(33, &cfg);
        let yf = m.forward(&xf);
        let yq = qm.forward(&quantize_tensor(&xf));
        for (f, q) in yf.iter().zip(&yq) {
            assert!(
                (f - q.to_f32()).abs() < 0.15,
                "float {f} vs quant {}",
                q.to_f32()
            );
        }
    }

    #[test]
    fn train_step_learns_single_sample() {
        let cfg = tiny();
        let m = Model::new(cfg.clone(), 35);
        let mut qm = QModel::from_model(&m);
        let x = quantize_tensor(&rand_image(37, &cfg));
        let lr = crate::fixed::Fx::from_f32(0.05);
        let first = qm.train_step(&x, 2, 4, lr).0;
        let mut last = first;
        for _ in 0..25 {
            last = qm.train_step(&x, 2, 4, lr).0;
        }
        assert!(last < first, "loss: first={first} last={last}");
        assert_eq!(qm.predict(&x, 4), 2);
    }

    #[test]
    fn train_step_deterministic() {
        let cfg = tiny();
        let m = Model::new(cfg.clone(), 39);
        let x = quantize_tensor(&rand_image(41, &cfg));
        let lr = crate::fixed::Fx::from_f32(0.1);
        let mut a = QModel::from_model(&m);
        let mut b = QModel::from_model(&m);
        for _ in 0..3 {
            a.train_step(&x, 1, 4, lr);
            b.train_step(&x, 1, 4, lr);
        }
        assert_eq!(a.params.w.data(), b.params.w.data());
        assert_eq!(a.params.k1.data(), b.params.k1.data());
    }

    #[test]
    fn engines_bit_identical_through_training() {
        // The tentpole invariant at unit scope: fast == naive, bit for
        // bit, on losses, predictions and every parameter, at batch 1
        // and batch > 1 and any thread count.
        let cfg = tiny();
        let m = Model::new(cfg.clone(), 43);
        let mut naive = QModel::from_model(&m).with_engine(QnnEngine::Naive);
        let mut fast = QModel::from_model(&m).with_engine(QnnEngine::Fast).with_threads(3);
        let lr = crate::fixed::Fx::from_f32(0.125);
        for step in 0..2 {
            let x = quantize_tensor(&rand_image(100 + step, &cfg));
            let ln = naive.train_step(&x, step as usize % 4, 4, lr);
            let lf = fast.train_step(&x, step as usize % 4, 4, lr);
            assert_eq!(ln, lf, "batch-1 step {step}");
        }
        let xs: Vec<Tensor<Fx>> =
            (0..3u64).map(|i| quantize_tensor(&rand_image(200 + i, &cfg))).collect();
        let refs: Vec<&Tensor<Fx>> = xs.iter().collect();
        let labels = [0usize, 1, 2];
        let ln = naive.train_batch(&refs, &labels, 4, lr);
        let lf = fast.train_batch(&refs, &labels, 4, lr);
        assert_eq!(ln, lf, "batch-3 loss/correct");
        assert_eq!(naive.params.w.data(), fast.params.w.data(), "w bits");
        assert_eq!(naive.params.k1.data(), fast.params.k1.data(), "k1 bits");
        assert_eq!(naive.params.k2.data(), fast.params.k2.data(), "k2 bits");
        assert_eq!(naive.step, fast.step, "step counters");
        let xe = quantize_tensor(&rand_image(300, &cfg));
        assert_eq!(naive.predict(&xe, 4), fast.predict(&xe, 4));
        assert_eq!(
            naive.forward_batch(&refs),
            fast.forward_batch(&refs),
            "batched logits"
        );
    }

    #[test]
    fn predict_batch_matches_per_sample() {
        let cfg = tiny();
        let m = Model::new(cfg.clone(), 47);
        let qm = QModel::from_model(&m);
        let xs: Vec<Tensor<Fx>> =
            (0..4u64).map(|i| quantize_tensor(&rand_image(400 + i, &cfg))).collect();
        let refs: Vec<&Tensor<Fx>> = xs.iter().collect();
        let batched = qm.predict_batch(&refs, 4);
        let singles: Vec<usize> = refs.iter().map(|x| qm.predict(x, 4)).collect();
        assert_eq!(batched, singles);
    }

    #[test]
    fn forward_to_cut_matches_cached_prefix_on_both_engines() {
        let cfg = tiny();
        let m = Model::new(cfg.clone(), 51);
        let naive = QModel::from_model(&m).with_engine(QnnEngine::Naive);
        let fast = QModel::from_model(&m).with_engine(QnnEngine::Fast).with_threads(3);
        let xs: Vec<Tensor<Fx>> =
            (0..3u64).map(|i| quantize_tensor(&rand_image(500 + i, &cfg))).collect();
        let refs: Vec<&Tensor<Fx>> = xs.iter().collect();
        for cut in 0..=crate::nn::MAX_CUT {
            let an = naive.forward_to_cut_batch(&refs, cut);
            let af = fast.forward_to_cut_batch(&refs, cut);
            for ((n, f), x) in an.iter().zip(&af).zip(&xs) {
                assert_eq!(n.data(), f.data(), "cut {cut} engine parity");
                match cut {
                    0 => assert_eq!(n.data(), x.data(), "cut 0 is the input"),
                    c => {
                        let cache = naive.forward_cached(x);
                        let oracle = if c == 1 { &cache.a1 } else { &cache.a2 };
                        assert_eq!(n.data(), oracle.data(), "cut {c} vs cached forward");
                    }
                }
            }
        }
    }

    #[test]
    fn train_from_cut0_is_train_batch_bit_exact() {
        let cfg = tiny();
        let m = Model::new(cfg.clone(), 52);
        for engine in [QnnEngine::Naive, QnnEngine::Fast] {
            let mut full = QModel::from_model(&m).with_engine(engine).with_threads(2);
            let mut via = full.clone();
            let xs: Vec<Tensor<Fx>> =
                (0..3u64).map(|i| quantize_tensor(&rand_image(600 + i, &cfg))).collect();
            let refs: Vec<&Tensor<Fx>> = xs.iter().collect();
            let labels = [1usize, 3, 0];
            let lr = Fx::from_f32(0.125);
            let a = full.train_batch(&refs, &labels, 4, lr);
            let b = via.train_batch_from(0, &refs, &labels, 4, lr);
            assert_eq!(a, b, "loss/correct");
            assert_eq!(full.params.w.data(), via.params.w.data(), "w bits");
            assert_eq!(full.params.k1.data(), via.params.k1.data(), "k1 bits");
            assert_eq!(full.params.k2.data(), via.params.k2.data(), "k2 bits");
            assert_eq!(full.step, via.step, "step counters");
        }
    }

    #[test]
    fn suffix_step_matches_full_step_and_freezes_prefix() {
        // From stored a1, the suffix step reproduces the full step's
        // k2/w bits exactly (their update sequence never consumes a
        // layer-1 gradient) while k1 stays frozen.
        let cfg = tiny();
        let m = Model::new(cfg.clone(), 53);
        for engine in [QnnEngine::Naive, QnnEngine::Fast] {
            let mut full = QModel::from_model(&m).with_engine(engine).with_threads(3);
            let mut sfx = full.clone();
            let xs: Vec<Tensor<Fx>> =
                (0..3u64).map(|i| quantize_tensor(&rand_image(700 + i, &cfg))).collect();
            let refs: Vec<&Tensor<Fx>> = xs.iter().collect();
            let labels = [2usize, 0, 1];
            let lr = Fx::from_f32(0.25);
            let (lf, cf) = full.train_batch(&refs, &labels, 4, lr);
            let a1s = sfx.forward_to_cut_batch(&refs, 1);
            let a1_refs: Vec<&Tensor<Fx>> = a1s.iter().collect();
            let (ls, cs) = sfx.train_batch_from(1, &a1_refs, &labels, 4, lr);
            assert_eq!(lf, ls, "loss bits ({engine:?})");
            assert_eq!(cf, cs, "correct count ({engine:?})");
            assert_eq!(full.params.w.data(), sfx.params.w.data(), "w bits");
            assert_eq!(full.params.k2.data(), sfx.params.k2.data(), "k2 bits");
            let entry_k1 = QParams::from_f32(&m.params).k1;
            assert_eq!(sfx.params.k1.data(), entry_k1.data(), "k1 frozen");
            assert_ne!(full.params.k1.data(), entry_k1.data(), "full path moves k1");
            assert_eq!(full.step, sfx.step, "step counters");
        }
    }

    #[test]
    fn dense_only_cut_freezes_both_convs_and_matches_across_engines() {
        let cfg = tiny();
        let m = Model::new(cfg.clone(), 54);
        let mut naive = QModel::from_model(&m).with_engine(QnnEngine::Naive);
        let mut fast = QModel::from_model(&m).with_engine(QnnEngine::Fast).with_threads(3);
        let xs: Vec<Tensor<Fx>> =
            (0..3u64).map(|i| quantize_tensor(&rand_image(800 + i, &cfg))).collect();
        let refs: Vec<&Tensor<Fx>> = xs.iter().collect();
        let a2s = naive.forward_to_cut_batch(&refs, 2);
        let a2_refs: Vec<&Tensor<Fx>> = a2s.iter().collect();
        let labels = [3usize, 1, 2];
        let lr = Fx::from_f32(0.25);
        let ln = naive.train_batch_from(2, &a2_refs, &labels, 4, lr);
        let lf = fast.train_batch_from(2, &a2_refs, &labels, 4, lr);
        assert_eq!(ln, lf, "loss/correct engine parity");
        assert_eq!(naive.params.w.data(), fast.params.w.data(), "w bits");
        assert_ne!(naive.params.w.data(), QParams::from_f32(&m.params).w.data(), "w moved");
        assert_eq!(naive.params.k1.data(), QParams::from_f32(&m.params).k1.data(), "k1 frozen");
        assert_eq!(naive.params.k2.data(), QParams::from_f32(&m.params).k2.data(), "k2 frozen");
        assert_eq!(naive.step, 3, "step still advances per sample");
    }

    #[test]
    fn packed_weights_bit_identical_and_invalidated_on_update() {
        let cfg = tiny();
        let m = Model::new(cfg.clone(), 57);
        let mut qm = QModel::from_model(&m).with_engine(QnnEngine::Fast).with_threads(2);
        let xs: Vec<Tensor<Fx>> =
            (0..3u64).map(|i| quantize_tensor(&rand_image(950 + i, &cfg))).collect();
        let refs: Vec<&Tensor<Fx>> = xs.iter().collect();
        let before = qm.forward_batch(&refs);
        qm.pack_weights();
        assert!(qm.packed.is_some());
        assert_eq!(qm.forward_batch(&refs), before, "packed forward must be bit-identical");
        // Every weight-update site must drop the pack (the forward
        // debug-asserts freshness, so a missed site also fails there).
        let lr = Fx::from_f32(0.125);
        qm.train_batch(&refs, &[0, 1, 2], 4, lr);
        assert!(qm.packed.is_none(), "train step kept a stale pack");
        qm.pack_weights();
        let a2s = qm.forward_to_cut_batch(&refs, 2);
        let a2_refs: Vec<&Tensor<Fx>> = a2s.iter().collect();
        qm.train_batch_from(2, &a2_refs, &[0, 1, 2], 4, lr);
        assert!(qm.packed.is_none(), "suffix step kept a stale pack");
        qm.pack_weights();
        qm.reinit_suffix(2, 9);
        assert!(qm.packed.is_none(), "reinit_suffix kept a stale pack");
    }

    #[test]
    fn reinit_suffix_cut0_is_full_reinit() {
        let cfg = tiny();
        let mut qm = QModel::from_model(&Model::new(cfg.clone(), 55))
            .with_engine(QnnEngine::Fast)
            .with_threads(2);
        let x = quantize_tensor(&rand_image(900, &cfg));
        qm.train_step(&x, 1, 4, Fx::from_f32(0.125));
        qm.reinit_suffix(0, 123);
        let fresh = QParams::from_f32(&Model::new(cfg, 123).params);
        assert_eq!(qm.params.k1.data(), fresh.k1.data());
        assert_eq!(qm.params.k2.data(), fresh.k2.data());
        assert_eq!(qm.params.w.data(), fresh.w.data());
        assert_eq!(qm.step, 0, "reinit resets the dither step");
        assert_eq!(qm.engine, QnnEngine::Fast, "engine preserved");
        assert_eq!(qm.threads, 2, "threads preserved");
    }

    #[test]
    fn reinit_suffix_keeps_frozen_prefix() {
        let cfg = tiny();
        let mut qm = QModel::from_model(&Model::new(cfg.clone(), 56));
        let before = qm.params.clone();
        qm.reinit_suffix(2, 321);
        let fresh = QParams::from_f32(&Model::new(cfg, 321).params);
        assert_eq!(qm.params.k1.data(), before.k1.data(), "k1 kept");
        assert_eq!(qm.params.k2.data(), before.k2.data(), "k2 kept");
        assert_eq!(qm.params.w.data(), fresh.w.data(), "w redrawn");
    }

    #[test]
    fn head_swap_round_trip_is_bit_exact() {
        let cfg = tiny();
        let mut qm = QModel::from_model(&Model::new(cfg.clone(), 60));
        let w0 = qm.params.w.data().to_vec();
        let t1 = qm.add_task_head(2, 77);
        assert_eq!((t1, qm.num_tasks(), qm.active_task()), (1, 2, 0));
        qm.set_active_task(t1).unwrap();
        assert_eq!(qm.out_classes(), 2);
        let expect = quantize_tensor(&crate::nn::fresh_head(&cfg, 2, 77));
        assert_eq!(qm.params.w.data(), expect.data(), "head must be the quantized float draw");
        qm.set_active_task(0).unwrap();
        assert_eq!(qm.params.w.data(), &w0[..], "round-trip swap must be bit-exact");
        assert!(qm.set_active_task(9).unwrap_err().contains("add_task_head"));
    }

    #[test]
    fn mixed_task_router_is_bit_exact_on_both_engines() {
        let cfg = tiny();
        let xs: Vec<Tensor<Fx>> =
            (0..4).map(|i| quantize_tensor(&rand_image(700 + i, &cfg))).collect();
        let refs: Vec<&Tensor<Fx>> = xs.iter().collect();
        for engine in [QnnEngine::Naive, QnnEngine::Fast] {
            let mut qm = QModel::from_model(&Model::new(cfg.clone(), 61))
                .with_engine(engine)
                .with_threads(2);
            let t1 = qm.add_task_head(2, 42);
            let tasks = [0usize, t1, 0, t1];
            let routed = qm.forward_batch_tasks(&refs, &tasks);
            for (bi, &t) in tasks.iter().enumerate() {
                qm.set_active_task(t).unwrap();
                assert_eq!(
                    routed[bi],
                    qm.forward(&xs[bi]),
                    "{engine:?} routed logits must be bit-identical, sample {bi}"
                );
            }
        }
    }

    #[test]
    fn frozen_backbone_ships_one_head_through_diff_sync() {
        let cfg = tiny();
        let mut src = QModel::from_model(&Model::new(cfg.clone(), 62));
        src.add_task_head(2, 43);
        src.add_task_head(2, 44);
        let mut replica = src.clone();
        let x = quantize_tensor(&rand_image(800, &cfg));
        let k1 = src.params.k1.data().to_vec();
        let head0 = src.head_view(0).data().to_vec();
        src.set_active_task(1).unwrap();
        src.set_freeze_backbone(true);
        src.train_step(&x, 0, 2, Fx::from_f32(0.125));
        assert_eq!(src.params.k1.data(), &k1[..], "frozen backbone moved");
        assert_eq!(src.head_view(0).data(), &head0[..], "parked head moved");
        let bytes = replica.sync_weights_from(&src);
        assert_eq!(bytes, src.head_bytes(1), "diff must ship exactly the trained head");
        for h in 0..src.num_tasks() {
            assert_eq!(replica.head_view(h).data(), src.head_view(h).data(), "head {h}");
        }
        assert_eq!(replica.step, src.step, "dither counter must travel with the diff");
    }
}
