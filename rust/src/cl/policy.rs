//! The CL policies and the task-stream runner.
//!
//! All policies see the same interface: a [`Task`]'s samples arrive once,
//! in stream order, and the policy decides what the learner trains on.
//! After each task the runner evaluates every seen task's test subset and
//! fills the [`AccuracyMatrix`].

use super::memory::{ReplayMemory, SamplerKind};
use super::metrics::{AccuracyMatrix, ClReport};
use super::stream::{Task, TaskStream};
use super::Learner;
use crate::data::{Dataset, Sample};
use crate::tensor::Tensor;

/// Hyper-parameters of one CL run (§IV-A: 10 epochs, lr 1, batch 1).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
    /// Minibatch size for training (paper: 1). Batched latent-replay
    /// minibatches are where CL training spends its time (Ravaglia et
    /// al.); the float backends turn each minibatch into one set of
    /// large GEMMs. Backends without a batched datapath fall back to
    /// per-sample steps (see [`Learner::train_batch`]).
    pub batch: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        // The paper trains "for 10 epochs, a learning rate of 1" — lr 1 is
        // only stable in the Q4.12 datapath's saturating arithmetic; the
        // float default uses a conventional rate (examples pass --lr 1 on
        // the quantized backends to match the paper exactly).
        RunConfig { epochs: 10, lr: 0.05, seed: 17, batch: 1 }
    }
}

/// Train `learner` on one minibatch of samples; returns how many
/// samples were presented (the unit `train_steps` counts).
fn train_minibatch(
    learner: &mut dyn Learner,
    samples: &[&Sample],
    active_classes: usize,
    lr: f32,
) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    let xs: Vec<&Tensor<f32>> = samples.iter().map(|s| &s.x).collect();
    let labels: Vec<usize> = samples.iter().map(|s| s.label).collect();
    learner.train_batch(&xs, &labels, active_classes, lr);
    samples.len() as u64
}

/// Epoch shuffle seed derived from `(run seed, task id, epoch)`. All the
/// epoch-shuffling policies mix all three so no two (task, epoch) pairs
/// replay the same permutation-seed sequence (the old `seed + epoch`
/// scheme repeated identically across tasks).
pub fn epoch_seed(seed: u64, task: usize, epoch: usize) -> u64 {
    seed ^ (task as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (epoch as u64).wrapping_mul(0xD134_2543_DE82_EF95)
}

/// A replay-memory budget, carried in both units so raw-sample policies
/// (slot-counted) and latent replay (byte-counted) stay comparable at an
/// equal byte budget.
#[derive(Clone, Copy, Debug)]
pub struct ReplayBudget {
    /// Whole raw samples that fit the budget (GDumb/ER capacity).
    pub slots: usize,
    /// The budget in bytes (latent replay divides this by its own
    /// per-activation footprint, which depends on the cut).
    pub bytes: u64,
}

impl ReplayBudget {
    /// From a slot count (the classic `--memory` knob); `sample_bytes` is
    /// the raw per-sample footprint (16-bit CHW values).
    pub fn from_slots(slots: usize, sample_bytes: u64) -> ReplayBudget {
        ReplayBudget { slots, bytes: slots as u64 * sample_bytes }
    }

    /// From a byte budget (`--memory-bytes`): raw-sample policies get as
    /// many whole samples as fit (at least one).
    pub fn from_bytes(bytes: u64, sample_bytes: u64) -> ReplayBudget {
        assert!(sample_bytes > 0);
        ReplayBudget { slots: ((bytes / sample_bytes) as usize).max(1), bytes }
    }
}

/// Which policy to instantiate (CLI/config surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    Gdumb,
    Er,
    Naive,
    Joint,
    LatentReplay,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 5] = [
        PolicyKind::Gdumb,
        PolicyKind::Er,
        PolicyKind::Naive,
        PolicyKind::Joint,
        PolicyKind::LatentReplay,
    ];

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Gdumb => "gdumb",
            PolicyKind::Er => "er",
            PolicyKind::Naive => "naive",
            PolicyKind::Joint => "joint",
            PolicyKind::LatentReplay => "latent-replay",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        PolicyKind::ALL.into_iter().find(|p| p.name() == s)
    }

    pub fn build(self, budget: ReplayBudget, replay_cut: usize, seed: u64) -> Box<dyn ClPolicy> {
        match self {
            PolicyKind::Gdumb => Box::new(Gdumb::new(budget.slots, seed)),
            PolicyKind::Er => Box::new(ExperienceReplay::new(budget.slots, seed)),
            PolicyKind::Naive => Box::new(NaiveFinetune::new()),
            PolicyKind::Joint => Box::new(JointUpperBound::new()),
            PolicyKind::LatentReplay => {
                Box::new(super::latent::LatentReplay::new(budget.bytes, replay_cut, seed))
            }
        }
    }
}

/// A continual-learning policy: consumes one task's stream and trains the
/// learner. Object-safe so the coordinator can pick policies at runtime.
pub trait ClPolicy {
    fn name(&self) -> &'static str;

    /// Observe one task (samples arrive once, in order) and train.
    /// Returns the number of train steps executed.
    fn observe_task(
        &mut self,
        learner: &mut dyn Learner,
        task: &Task,
        dataset: &Dataset,
        active_classes: usize,
        cfg: &RunConfig,
    ) -> u64;

    /// Cumulative replay-memory traffic `(reads, writes)` in 128-bit
    /// bursts (zero for memory-less policies).
    fn replay_traffic(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// GDumb [24]: greedily keep a class-balanced memory; after each task,
/// re-initialize the learner ("dumb") and train from scratch on the
/// memory only. This is the paper's policy (§IV-A).
pub struct Gdumb {
    pub memory: ReplayMemory,
    reinit_counter: u64,
}

impl Gdumb {
    pub fn new(budget: usize, seed: u64) -> Gdumb {
        Gdumb {
            memory: ReplayMemory::new(SamplerKind::GreedyBalanced, budget, seed),
            reinit_counter: 0,
        }
    }
}

impl ClPolicy for Gdumb {
    fn name(&self) -> &'static str {
        "gdumb"
    }

    fn observe_task(
        &mut self,
        learner: &mut dyn Learner,
        task: &Task,
        dataset: &Dataset,
        active_classes: usize,
        cfg: &RunConfig,
    ) -> u64 {
        for &i in &task.sample_indices {
            self.memory.offer(&dataset.samples[i]);
        }
        // Dumb learner: from scratch on the (balanced) memory, in
        // shuffled minibatches of `cfg.batch`.
        self.reinit_counter += 1;
        learner.reinit(cfg.seed ^ (self.reinit_counter << 32));
        let mut steps = 0;
        for epoch in 0..cfg.epochs {
            let epoch_seed = epoch_seed(cfg.seed, task.id, epoch);
            for chunk in self.memory.epoch_batches(epoch_seed, cfg.batch) {
                let refs: Vec<&Sample> = chunk.iter().collect();
                steps += train_minibatch(learner, &refs, active_classes, cfg.lr);
            }
        }
        steps
    }

    fn replay_traffic(&self) -> (u64, u64) {
        (self.memory.read_bursts, self.memory.write_bursts)
    }
}

/// Experience Replay [21]: train on each arriving sample interleaved with
/// one sample drawn from a reservoir memory; never re-initializes.
pub struct ExperienceReplay {
    pub memory: ReplayMemory,
}

impl ExperienceReplay {
    pub fn new(budget: usize, seed: u64) -> ExperienceReplay {
        ExperienceReplay { memory: ReplayMemory::new(SamplerKind::Reservoir, budget, seed) }
    }
}

impl ClPolicy for ExperienceReplay {
    fn name(&self) -> &'static str {
        "er"
    }

    fn observe_task(
        &mut self,
        learner: &mut dyn Learner,
        task: &Task,
        dataset: &Dataset,
        active_classes: usize,
        cfg: &RunConfig,
    ) -> u64 {
        let mut steps = 0;
        let batch = cfg.batch.max(1);
        for _epoch in 0..cfg.epochs {
            for idx_chunk in task.sample_indices.chunks(batch) {
                let fresh: Vec<&Sample> =
                    idx_chunk.iter().map(|&i| &dataset.samples[i]).collect();
                steps += train_minibatch(learner, &fresh, active_classes, cfg.lr);
                // Interleave an equal-sized replay minibatch (the
                // batch-1 special case is classic ER: one new, one old).
                let replay = self.memory.draw(idx_chunk.len());
                let replay_refs: Vec<&Sample> = replay.iter().collect();
                steps += train_minibatch(learner, &replay_refs, active_classes, cfg.lr);
            }
        }
        // Admit after training so replay draws never contain the current
        // task's own samples at full density (standard ER ordering keeps
        // this per-sample; per-task admission is equivalent under our
        // single-pass offer and keeps the reservoir denominator exact).
        for &i in &task.sample_indices {
            self.memory.offer(&dataset.samples[i]);
        }
        steps
    }

    fn replay_traffic(&self) -> (u64, u64) {
        (self.memory.read_bursts, self.memory.write_bursts)
    }
}

/// Naive fine-tuning: train on the new task only — the catastrophic-
/// forgetting lower bound every CL paper measures against.
pub struct NaiveFinetune;

impl NaiveFinetune {
    #[allow(clippy::new_without_default)]
    pub fn new() -> NaiveFinetune {
        NaiveFinetune
    }
}

impl ClPolicy for NaiveFinetune {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn observe_task(
        &mut self,
        learner: &mut dyn Learner,
        task: &Task,
        dataset: &Dataset,
        active_classes: usize,
        cfg: &RunConfig,
    ) -> u64 {
        let mut steps = 0;
        for _ in 0..cfg.epochs {
            for idx_chunk in task.sample_indices.chunks(cfg.batch.max(1)) {
                let refs: Vec<&Sample> =
                    idx_chunk.iter().map(|&i| &dataset.samples[i]).collect();
                steps += train_minibatch(learner, &refs, active_classes, cfg.lr);
            }
        }
        steps
    }
}

/// Joint training on everything seen so far (from scratch per task) —
/// the no-forgetting upper bound (unbounded memory).
pub struct JointUpperBound {
    seen: Vec<Sample>,
    reinit_counter: u64,
}

impl JointUpperBound {
    #[allow(clippy::new_without_default)]
    pub fn new() -> JointUpperBound {
        JointUpperBound { seen: Vec::new(), reinit_counter: 0 }
    }
}

impl ClPolicy for JointUpperBound {
    fn name(&self) -> &'static str {
        "joint"
    }

    fn observe_task(
        &mut self,
        learner: &mut dyn Learner,
        task: &Task,
        dataset: &Dataset,
        active_classes: usize,
        cfg: &RunConfig,
    ) -> u64 {
        self.seen.extend(task.sample_indices.iter().map(|&i| dataset.samples[i].clone()));
        self.reinit_counter += 1;
        learner.reinit(cfg.seed ^ (self.reinit_counter << 24));
        let mut order: Vec<usize> = (0..self.seen.len()).collect();
        let mut steps = 0;
        for epoch in 0..cfg.epochs {
            // Same (seed, task, epoch) derivation as the replay policies'
            // epoch shuffles, on Joint's own stream id.
            let mut rng =
                crate::util::rng::Pcg32::new(epoch_seed(cfg.seed, task.id, epoch), 0x10);
            rng.shuffle(&mut order);
            for idx_chunk in order.chunks(cfg.batch.max(1)) {
                let refs: Vec<&Sample> = idx_chunk.iter().map(|&i| &self.seen[i]).collect();
                steps += train_minibatch(learner, &refs, active_classes, cfg.lr);
            }
        }
        steps
    }
}

/// Batched-forward chunk size shared by accuracy evaluation and the
/// serving batcher's default `max_batch` (`serve::ServerConfig`).
/// Predictions are independent, so chunking is purely a throughput knob
/// — backends with a batched forward run one packed GEMM set per chunk,
/// the rest fall back to per-sample prediction (see
/// [`Learner::predict_batch`]). 64 because at the paper geometry it is
/// past the knee of the amortization curve: the packed conv GEMMs span
/// tens of thousands of output columns (64 × 1024 pixels), far beyond
/// the worker pool's `MT_MIN_MACS` threshold with full column-sharding
/// headroom, and per-call overheads (pool dispatch, packing-buffer
/// allocation) are split 64 ways — while the chunk's im2col workspace
/// (~400 KB per sample, ~25 MB per chunk) stays a trivial host-memory
/// footprint. Larger chunks only grow the workspace without measurably
/// improving per-sample cost; much smaller ones re-pay the dispatch
/// overhead per call.
pub const EVAL_BATCH: usize = 64;

/// Accuracy of `learner` on the test subset of `task`, head masked to
/// `active_classes`. Evaluates in [`EVAL_BATCH`]-sized minibatches
/// through [`Learner::predict_batch`] — bit-identical to the per-sample
/// sweep (`tests/qnn_fast_parity.rs` pins the parity).
pub fn evaluate(
    learner: &mut dyn Learner,
    task: &Task,
    test: &Dataset,
    active_classes: usize,
) -> f64 {
    let subset = test.task_subset(&task.classes);
    assert!(!subset.is_empty(), "empty test subset for task {}", task.id);
    let mut correct = 0usize;
    for chunk in subset.chunks(EVAL_BATCH) {
        let xs: Vec<&Tensor<f32>> = chunk.iter().map(|s| &s.x).collect();
        let preds = learner.predict_batch(&xs, active_classes);
        correct += preds.iter().zip(chunk).filter(|(p, s)| **p == s.label).count();
    }
    correct as f64 / subset.len() as f64
}

/// Run a whole CL experiment: stream the tasks through the policy,
/// evaluating after each task. The paper's E5 driver.
pub fn run_stream(
    policy: &mut dyn ClPolicy,
    learner: &mut dyn Learner,
    stream: &TaskStream,
    train: &Dataset,
    test: &Dataset,
    cfg: &RunConfig,
) -> ClReport {
    let mut matrix = AccuracyMatrix::new(stream.num_tasks());
    let mut train_steps = 0;
    // Per-task phase timing: CL work alternates a train phase
    // (observe_task: epochs + replay) with an eval phase (the accuracy
    // row over all tasks seen so far). Wall-clock, not MockClock — CL
    // runs are offline benches, not served traffic.
    let train_us = crate::obs::histogram("cl_train_phase_us");
    let eval_us = crate::obs::histogram("cl_eval_phase_us");
    let tasks_total = crate::obs::counter("cl_tasks_total");
    for (t, task) in stream.tasks.iter().enumerate() {
        let active = stream.active_classes_after(t);
        let t0 = std::time::Instant::now();
        train_steps += policy.observe_task(learner, task, train, active, cfg);
        crate::obs::record_us(train_us, t0.elapsed().as_micros() as u64);
        let t1 = std::time::Instant::now();
        let row: Vec<f64> = stream.tasks[..=t]
            .iter()
            .map(|seen| evaluate(learner, seen, test, active))
            .collect();
        crate::obs::record_us(eval_us, t1.elapsed().as_micros() as u64);
        tasks_total.inc();
        matrix.push_row(row);
    }
    ClReport {
        policy: policy.name().to_string(),
        matrix,
        train_steps,
        replay_bursts: {
            let (r, w) = policy.replay_traffic();
            (r, w)
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticCifar;
    use crate::nn::{Model, ModelConfig};

    fn setup(per_class: usize) -> (Dataset, Dataset, TaskStream, Model) {
        let gen = SyntheticCifar { image_size: 16, ..Default::default() };
        let train = gen.generate(per_class, 0);
        let test = gen.generate(4, 1);
        let stream = TaskStream::paper(&train, 5);
        let cfg = ModelConfig {
            in_channels: 3,
            image_size: 16,
            conv_channels: 4,
            num_classes: 10,
            grad_clip: 1.0,
        };
        let model = Model::new(cfg, 77);
        (train, test, stream, model)
    }

    fn quick_cfg() -> RunConfig {
        RunConfig { epochs: 3, lr: 0.05, seed: 5, batch: 1 }
    }

    #[test]
    fn gdumb_learns_all_tasks_above_chance() {
        let (train, test, stream, mut model) = setup(12);
        let mut policy = Gdumb::new(60, 1);
        let report = run_stream(&mut policy, &mut model, &stream, &train, &test, &quick_cfg());
        assert_eq!(report.matrix.rows_filled(), 5);
        // 10-way chance is 0.1; GDumb's balanced memory should beat it
        // clearly on the final average.
        assert!(
            report.final_average() > 0.25,
            "gdumb avg {:.3} not above chance\n{}",
            report.final_average(),
            report
        );
    }

    #[test]
    fn naive_forgets_more_than_gdumb() {
        let (train, test, stream, mut model) = setup(12);
        let cfg = quick_cfg();
        let mut gdumb = Gdumb::new(60, 1);
        let g = run_stream(&mut gdumb, &mut model, &stream, &train, &test, &cfg);
        model.reinit(77);
        let mut naive = NaiveFinetune::new();
        let n = run_stream(&mut naive, &mut model, &stream, &train, &test, &cfg);
        assert!(
            n.matrix.forgetting() > g.matrix.forgetting(),
            "naive forgetting {:.3} <= gdumb {:.3}",
            n.matrix.forgetting(),
            g.matrix.forgetting()
        );
    }

    #[test]
    fn joint_upper_bounds_naive() {
        let (train, test, stream, mut model) = setup(10);
        let cfg = quick_cfg();
        let mut joint = JointUpperBound::new();
        let j = run_stream(&mut joint, &mut model, &stream, &train, &test, &cfg);
        model.reinit(77);
        let mut naive = NaiveFinetune::new();
        let n = run_stream(&mut naive, &mut model, &stream, &train, &test, &cfg);
        assert!(
            j.final_average() > n.final_average(),
            "joint {:.3} <= naive {:.3}",
            j.final_average(),
            n.final_average()
        );
    }

    #[test]
    fn er_tracks_memory_traffic() {
        let (train, test, stream, mut model) = setup(6);
        let mut er = ExperienceReplay::new(30, 2);
        let report = run_stream(&mut er, &mut model, &stream, &train, &test, &quick_cfg());
        let (reads, writes) = report.replay_bursts;
        assert!(writes > 0, "ER never wrote to memory");
        assert!(reads > 0, "ER never replayed");
    }

    #[test]
    fn step_counts_match_policy_semantics() {
        let (train, test, stream, mut model) = setup(6);
        let cfg = quick_cfg();
        // Naive: epochs × samples-per-task × tasks.
        let mut naive = NaiveFinetune::new();
        let n = run_stream(&mut naive, &mut model, &stream, &train, &test, &cfg);
        assert_eq!(n.train_steps, (cfg.epochs * 12 * 5) as u64);
        // GDumb: epochs × memory-size after each task.
        model.reinit(1);
        let mut gdumb = Gdumb::new(1000, 3);
        let g = run_stream(&mut gdumb, &mut model, &stream, &train, &test, &cfg);
        // Memory never exceeds the seen sample count here (60 < 1000):
        // after task t, memory = 12(t+1) samples.
        let expect: u64 = (1..=5).map(|t| (cfg.epochs * 12 * t) as u64).sum();
        assert_eq!(g.train_steps, expect);
    }

    #[test]
    fn gdumb_learns_in_minibatches_too() {
        // Same experiment at batch 8: step counts are unchanged (steps
        // count sample presentations) and the learner still clearly
        // beats chance — minibatching must not break the CL loop.
        let (train, test, stream, mut model) = setup(12);
        // Linear lr scaling: mean-gradient minibatches make ~1/B as many
        // updates, so lr grows by B to cover the same ground.
        let cfg = RunConfig { batch: 8, lr: 0.4, ..quick_cfg() };
        let mut policy = Gdumb::new(60, 1);
        let report = run_stream(&mut policy, &mut model, &stream, &train, &test, &cfg);
        assert_eq!(report.matrix.rows_filled(), 5);
        assert!(
            report.final_average() > 0.2,
            "batched gdumb avg {:.3} not above chance\n{}",
            report.final_average(),
            report
        );
        let expect: u64 = (1..=5).map(|t| (cfg.epochs * 12 * t) as u64).sum();
        assert_eq!(report.train_steps, expect, "batching changed the step accounting");
    }

    #[test]
    fn epoch_seeds_distinct_across_tasks_and_epochs() {
        // The pre-fix scheme (`seed + epoch`) collided across tasks; the
        // mixed derivation must give every (task, epoch) its own seed.
        let mut seen = std::collections::BTreeSet::new();
        for task in 0..16 {
            for epoch in 0..32 {
                assert!(
                    seen.insert(epoch_seed(17, task, epoch)),
                    "epoch seed collision at task {task}, epoch {epoch}"
                );
            }
        }
    }

    #[test]
    fn policy_kind_roundtrip() {
        for k in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(k.name()), Some(k));
        }
        assert_eq!(PolicyKind::parse("nope"), None);
    }
}
