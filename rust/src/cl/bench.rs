//! `tinycl replay-bench` — the latent-replay memory–latency–accuracy
//! frontier (ROADMAP item 2).
//!
//! Sweeps replay byte budgets × cut points and runs the raw-sample
//! baselines (gdumb, er) at the *same byte budgets*, so every point
//! answers the deployment question the paper's 6.144 MB memory poses:
//! given this many bytes of replay SRAM, is it better to hold raw
//! samples and train the whole network, or activations at a cut and
//! train only the suffix? Activations at the paper geometry are larger
//! per slot (8×32×32 vs 3×32×32 values), so a latent memory holds ~2.7×
//! fewer samples — but each epoch skips the frozen prefix entirely,
//! which is where the ≥ 2× train-time win asserted below comes from.
//!
//! Conventions follow `serve-bench`: `--smoke` shrinks the geometry for
//! CI and relaxes the ratio asserts; results land in `BENCH_replay.json`
//! (one object per run) so the driver can track the frontier across PRs.

use super::metrics::AccuracyMatrix;
use super::policy::{self, ClPolicy, ExperienceReplay, Gdumb, ReplayBudget, RunConfig};
use super::stream::TaskStream;
use super::LatentReplay;
use crate::coordinator::{Backend, BackendKind};
use crate::data::{Dataset, SyntheticCifar};
use crate::nn::{ModelConfig, MAX_CUT};
use crate::qnn::QnnEngine;
use crate::sim::SimConfig;
use crate::util::cli::Args;
use crate::util::json::{Json, Obj};
use anyhow::Result;
use std::time::Instant;

/// One (policy, budget) point on the frontier.
struct RunRecord {
    policy: &'static str,
    cut: Option<usize>,
    budget_bytes: u64,
    slot_bytes: u64,
    capacity_slots: usize,
    stored_slots: usize,
    final_avg_acc: f64,
    forgetting: f64,
    train_secs: f64,
    train_steps: u64,
    replay_read_bursts: u64,
    replay_write_bursts: u64,
}

impl RunRecord {
    fn to_json_value(&self) -> Json {
        let mut o = Obj::new();
        o.put("policy", self.policy);
        o.put("cut", self.cut.map_or(Json::Null, Json::from));
        o.put("budget_bytes", self.budget_bytes);
        o.put("slot_bytes", self.slot_bytes);
        o.put("capacity_slots", self.capacity_slots);
        o.put("stored_slots", self.stored_slots);
        o.put("final_avg_acc", Json::fixed(self.final_avg_acc, 4));
        o.put("forgetting", Json::fixed(self.forgetting, 4));
        o.put("train_secs", Json::fixed(self.train_secs, 4));
        o.put("train_steps", self.train_steps);
        o.put("replay_read_bursts", self.replay_read_bursts);
        o.put("replay_write_bursts", self.replay_write_bursts);
        o.build()
    }
}

struct Setup {
    model: ModelConfig,
    backend: BackendKind,
    qnn_engine: QnnEngine,
    threads: usize,
    stream: TaskStream,
    train: Dataset,
    test: Dataset,
    run_cfg: RunConfig,
}

impl Setup {
    fn backend(&self) -> Result<Backend> {
        let mut b = Backend::create(
            self.backend,
            &self.model,
            &SimConfig::paper(),
            "artifacts",
            self.run_cfg.seed,
        )?;
        b.set_threads(self.threads);
        b.set_qnn_engine(self.qnn_engine);
        Ok(b)
    }
}

/// Drive one full task stream, timing only the training windows
/// (`observe_task`); evaluation is common to every policy and excluded.
fn drive(
    policy: &mut dyn ClPolicy,
    backend: &mut Backend,
    setup: &Setup,
) -> (AccuracyMatrix, f64, u64) {
    let mut matrix = AccuracyMatrix::new(setup.stream.num_tasks());
    let mut steps = 0;
    let mut secs = 0.0;
    for (t, task) in setup.stream.tasks.iter().enumerate() {
        let active = setup.stream.active_classes_after(t);
        let t0 = Instant::now();
        steps += policy.observe_task(backend, task, &setup.train, active, &setup.run_cfg);
        secs += t0.elapsed().as_secs_f64();
        let row: Vec<f64> = setup.stream.tasks[..=t]
            .iter()
            .map(|seen| policy::evaluate(backend, seen, &setup.test, active))
            .collect();
        matrix.push_row(row);
    }
    (matrix, secs, steps)
}

fn record(
    policy: &'static str,
    cut: Option<usize>,
    budget_bytes: u64,
    memory: (u64, usize, usize, u64, u64),
    matrix: &AccuracyMatrix,
    train_secs: f64,
    train_steps: u64,
) -> RunRecord {
    let (slot_bytes, capacity_slots, stored_slots, reads, writes) = memory;
    RunRecord {
        policy,
        cut,
        budget_bytes,
        slot_bytes,
        capacity_slots,
        stored_slots,
        final_avg_acc: matrix.final_average(),
        forgetting: matrix.forgetting(),
        train_secs,
        train_steps,
        replay_read_bursts: reads,
        replay_write_bursts: writes,
    }
}

fn run_one(setup: &Setup, budget_bytes: u64, cut: Option<usize>) -> Result<RunRecord> {
    let sample_bytes = setup.model.sample_bytes();
    let mut backend = setup.backend()?;
    let seed = setup.run_cfg.seed;
    Ok(match cut {
        None => {
            // Raw-sample baseline at the same byte budget.
            let budget = ReplayBudget::from_bytes(budget_bytes, sample_bytes);
            let mut p = Gdumb::new(budget.slots, seed);
            let (matrix, secs, steps) = drive(&mut p, &mut backend, setup);
            let memory = (
                sample_bytes,
                p.memory.capacity(),
                p.memory.len(),
                p.memory.read_bursts,
                p.memory.write_bursts,
            );
            record("gdumb", None, budget_bytes, memory, &matrix, secs, steps)
        }
        Some(c) => {
            let mut p = LatentReplay::new(budget_bytes, c, seed);
            let (matrix, secs, steps) = drive(&mut p, &mut backend, setup);
            let (reads, writes) = p.memory.traffic();
            let memory = (
                p.memory.slot_bytes().unwrap_or(0),
                p.memory.capacity().unwrap_or(0),
                p.memory.len(),
                reads,
                writes,
            );
            record("latent-replay", Some(c), budget_bytes, memory, &matrix, secs, steps)
        }
    })
}

/// The `er` baseline is a separate shape (reservoir, no re-init), so it
/// gets its own runner rather than a third arm above.
fn run_er(setup: &Setup, budget_bytes: u64) -> Result<RunRecord> {
    let sample_bytes = setup.model.sample_bytes();
    let mut backend = setup.backend()?;
    let budget = ReplayBudget::from_bytes(budget_bytes, sample_bytes);
    let mut p = ExperienceReplay::new(budget.slots, setup.run_cfg.seed);
    let (matrix, secs, steps) = drive(&mut p, &mut backend, setup);
    Ok(RunRecord {
        policy: "er",
        cut: None,
        budget_bytes,
        slot_bytes: sample_bytes,
        capacity_slots: p.memory.capacity(),
        stored_slots: p.memory.len(),
        final_avg_acc: matrix.final_average(),
        forgetting: matrix.forgetting(),
        train_secs: secs,
        train_steps: steps,
        replay_read_bursts: p.memory.read_bursts,
        replay_write_bursts: p.memory.write_bursts,
    })
}

pub fn run(args: &Args) -> Result<()> {
    let smoke = args.bool_or("smoke", false);
    let model = if smoke {
        ModelConfig {
            in_channels: 3,
            image_size: 8,
            conv_channels: 4,
            num_classes: 4,
            grad_clip: 1.0,
        }
    } else {
        ModelConfig { grad_clip: 1.0, ..ModelConfig::default() }
    };
    let backend = {
        let s = args.str_or("backend", "f32-fast");
        let kind = BackendKind::parse(&s)
            .ok_or_else(|| anyhow::anyhow!("unknown backend '{s}' (f32|f32-fast|qnn)"))?;
        if !matches!(kind, BackendKind::F32 | BackendKind::F32Fast | BackendKind::Qnn) {
            anyhow::bail!("backend '{s}' has no cut-point datapath — use f32, f32-fast or qnn");
        }
        kind
    };
    let num_tasks = args.usize_or("tasks", if smoke { 2 } else { 5 });
    let seed = args.u64_or("seed", 17);
    let run_cfg = RunConfig {
        epochs: args.usize_or("epochs", if smoke { 2 } else { 3 }),
        lr: args.f32_or("lr", 0.05),
        seed,
        batch: args.usize_or("batch", if smoke { 4 } else { 8 }).max(1),
    };
    let gen = SyntheticCifar {
        image_size: model.image_size,
        channels: model.in_channels,
        num_classes: model.num_classes,
        noise: 0.35,
        seed,
    };
    let per_class = args.usize_or("per-class", if smoke { 6 } else { 60 });
    let test_per_class = args.usize_or("test-per-class", if smoke { 4 } else { 20 });
    let train = gen.generate(per_class, 0);
    let test = gen.generate(test_per_class, 1);
    let setup = Setup {
        stream: TaskStream::class_incremental(&train, num_tasks, seed),
        train,
        test,
        backend,
        qnn_engine: QnnEngine::from_args(args)?,
        threads: args.threads_or_auto("threads", 0),
        run_cfg,
        model,
    };
    // Byte budgets: the paper's 6.144 MB memory and two halvings (kB
    // here = 1000 B, matching the paper's 6144 kB = 1000 raw slots).
    let budgets: Vec<u64> = if smoke {
        args.usize_list_or("budgets-kb", "6,3").iter().map(|&k| k as u64 * 1000).collect()
    } else {
        args.usize_list_or("budgets-kb", "6144,3072,1536")
            .iter()
            .map(|&k| k as u64 * 1000)
            .collect()
    };
    anyhow::ensure!(!budgets.is_empty(), "--budgets-kb must name at least one budget");
    let mode = if smoke { "smoke" } else { "paper" };
    println!(
        "replay-bench [{mode}]: backend={} tasks={} epochs={} batch={} budgets={budgets:?} B",
        setup.backend.name(),
        num_tasks,
        setup.run_cfg.epochs,
        setup.run_cfg.batch,
    );

    let mut runs: Vec<RunRecord> = Vec::new();
    for &budget in &budgets {
        println!("\n--- byte budget {budget} ---");
        let mut batch = vec![run_one(&setup, budget, None)?, run_er(&setup, budget)?];
        for cut in 0..=MAX_CUT {
            batch.push(run_one(&setup, budget, Some(cut))?);
        }
        for r in &batch {
            let cut = match r.cut {
                Some(c) => c.to_string(),
                None => "-".to_string(),
            };
            println!(
                "{:>13} cut={cut} slots={}/{} ({} B/slot): acc {:.3} forgetting {:.3} \
                 train {:.2}s ({} steps)",
                r.policy,
                r.stored_slots,
                r.capacity_slots,
                r.slot_bytes,
                r.final_avg_acc,
                r.forgetting,
                r.train_secs,
                r.train_steps,
            );
        }
        runs.extend(batch);
    }

    // Train-epoch speedup of each interior cut vs gdumb at the largest
    // (the paper's) budget — the frontier's latency axis.
    let largest = *budgets.iter().max().unwrap();
    let gdumb_secs = runs
        .iter()
        .find(|r| r.policy == "gdumb" && r.budget_bytes == largest)
        .map(|r| r.train_secs)
        .unwrap();
    let interior: Vec<(usize, f64)> = (1..=MAX_CUT)
        .filter_map(|c| {
            runs.iter()
                .find(|r| r.cut == Some(c) && r.budget_bytes == largest)
                .map(|r| (c, gdumb_secs / r.train_secs.max(1e-12)))
        })
        .collect();
    println!();
    for &(c, s) in &interior {
        println!("cut {c} vs gdumb at {largest} B: {s:.2}× faster training");
    }

    // On the quantized backend, cut 0 *is* gdumb — the latent store
    // round-trips the Q4.12 inputs exactly, so the whole run must agree.
    if setup.backend == BackendKind::Qnn {
        for &budget in &budgets {
            let g = runs.iter().find(|r| r.policy == "gdumb" && r.budget_bytes == budget).unwrap();
            let l = runs.iter().find(|r| r.cut == Some(0) && r.budget_bytes == budget).unwrap();
            assert_eq!(g.final_avg_acc, l.final_avg_acc, "qnn cut-0 accuracy parity at {budget} B");
            assert_eq!(g.train_steps, l.train_steps, "qnn cut-0 step parity at {budget} B");
        }
        println!("qnn cut-0 runs match gdumb exactly (accuracy and step counts)");
    }

    let mut geometry = Obj::new();
    geometry.put("image_size", setup.model.image_size);
    geometry.put("in_channels", setup.model.in_channels);
    geometry.put("conv_channels", setup.model.conv_channels);
    geometry.put("classes", setup.model.num_classes);
    let mut speedups_obj = Obj::new();
    for &(c, s) in &interior {
        speedups_obj.put(&format!("cut{c}"), Json::fixed(s, 2));
    }
    let mut doc = Obj::new();
    doc.put("bench", "replay");
    doc.put("mode", mode);
    doc.put("geometry", geometry.build());
    doc.put("backend", setup.backend.name());
    doc.put("tasks", num_tasks);
    doc.put("epochs", setup.run_cfg.epochs);
    doc.put("batch", setup.run_cfg.batch);
    doc.put("threads", setup.threads);
    doc.put("sample_bytes", setup.model.sample_bytes());
    doc.put("budgets_bytes", Json::Arr(budgets.iter().map(|&b| Json::from(b)).collect()));
    doc.put("interior_speedup", speedups_obj.build());
    doc.put("runs", Json::Arr(runs.iter().map(RunRecord::to_json_value).collect()));
    let json = doc.build().to_pretty(2);
    match std::fs::write("BENCH_replay.json", &json) {
        Ok(()) => println!("wrote BENCH_replay.json"),
        Err(e) => eprintln!("WARN: could not write BENCH_replay.json: {e}"),
    }
    if let Some(path) = args.get("metrics-json") {
        match std::fs::write(path, crate::obs::export::json_snapshot()) {
            Ok(()) => println!("wrote metrics snapshot to {path}"),
            Err(e) => eprintln!("WARN: could not write {path}: {e}"),
        }
    }

    // Ratio gate only at the paper geometry (repo convention: smoke
    // keeps CI honest about plumbing, not performance).
    if !smoke {
        let best = interior.iter().map(|&(_, s)| s).fold(0.0f64, f64::max);
        assert!(
            best >= 2.0,
            "expected an interior cut to train ≥ 2× faster than gdumb at equal bytes, got {best:.2}×"
        );
    }

    println!("\nreplay-bench PASS");
    Ok(())
}
