//! Continual-learning policies and evaluation — the workload layer the
//! paper's control unit exists to serve (§II-B, §III-E "Training Data
//! Memory", §IV-A).
//!
//! The paper trains its Conv-Conv-Dense model over **5 tasks × 2 classes**
//! of CIFAR-10 "following the GDumb approach [24]" with a 6.144 MB replay
//! memory (1000 samples). We implement GDumb exactly, plus the baselines a
//! CL evaluation needs to be interpretable:
//! * [`policy::Gdumb`] — greedy class-balanced sampler + train-from-scratch
//!   dumb learner (the paper's policy);
//! * [`policy::ExperienceReplay`] — interleaves new samples with reservoir
//!   replay (no re-init) [21];
//! * [`policy::NaiveFinetune`] — lower bound: no memory, full forgetting;
//! * [`policy::JointUpperBound`] — trains on everything seen (oracle);
//! * [`latent::LatentReplay`] — stores Q4.12 *activations* at a cut point
//!   and trains only the suffix (the memory–latency–accuracy frontier,
//!   `tinycl replay-bench`).
//!
//! Policies are generic over a [`Learner`] so the same algorithm runs on
//! any backend: the f32 reference, the bit-exact Q4.12 model, the
//! cycle-accurate device, or the AOT-compiled XLA executable (see
//! `coordinator`).

pub mod bench;
pub mod latent;
pub mod memory;
pub mod metrics;
pub mod policy;
pub mod stream;

pub use latent::{LatentMemory, LatentReplay};
pub use memory::{ReplayMemory, ReplayStore, Replayable, SamplerKind};
pub use metrics::{AccuracyMatrix, ClReport};
pub use policy::EVAL_BATCH;
pub use policy::{
    epoch_seed, ClPolicy, ExperienceReplay, Gdumb, JointUpperBound, NaiveFinetune, PolicyKind,
    ReplayBudget, RunConfig,
};
pub use stream::{Task, TaskStream};

use crate::tensor::Tensor;

/// Sequential per-sample minibatch fallback: one [`Learner::train_step`]
/// per sample, in order. Shared by the trait's default `train_batch` and
/// by backend overrides for engines without a batched datapath, so the
/// two can never drift. Returns the mean loss.
pub fn train_batch_sequential<L: Learner + ?Sized>(
    learner: &mut L,
    xs: &[&Tensor<f32>],
    labels: &[usize],
    active_classes: usize,
    lr: f32,
) -> f32 {
    assert_eq!(xs.len(), labels.len(), "batch inputs vs labels");
    assert!(!xs.is_empty(), "empty batch");
    let mut sum = 0.0;
    for (x, &label) in xs.iter().zip(labels) {
        sum += learner.train_step(x, label, active_classes, lr);
    }
    sum / xs.len() as f32
}

/// Group-and-swap mixed-task routing fallback: samples grouped by
/// (task, active mask), each head swapped in via
/// [`Learner::set_active_task`], results assembled in input order, the
/// entry task restored. Shared by the trait's default
/// `predict_batch_tasks` and by backend dispatchers whose variants lack
/// a native router, so the two can never drift.
pub fn default_predict_batch_tasks<L: Learner + ?Sized>(
    learner: &mut L,
    xs: &[&Tensor<f32>],
    tasks: &[usize],
    actives: &[usize],
) -> Vec<usize> {
    assert_eq!(xs.len(), tasks.len(), "batch inputs vs tasks");
    assert_eq!(xs.len(), actives.len(), "batch inputs vs active masks");
    let entry = learner.active_task();
    let mut groups: std::collections::BTreeMap<(usize, usize), Vec<usize>> =
        std::collections::BTreeMap::new();
    for (i, (&t, &a)) in tasks.iter().zip(actives).enumerate() {
        groups.entry((t, a)).or_default().push(i);
    }
    let mut out = vec![0usize; xs.len()];
    for ((task, active), idxs) in groups {
        learner
            .set_active_task(task)
            .unwrap_or_else(|e| panic!("predict routed to a missing head: {e}"));
        let gxs: Vec<&Tensor<f32>> = idxs.iter().map(|&i| xs[i]).collect();
        for (&i, p) in idxs.iter().zip(learner.predict_batch(&gxs, active)) {
            out[i] = p;
        }
    }
    learner.set_active_task(entry).expect("entry task vanished during routing");
    out
}

/// A trainable classifier backend. `active_classes` masks the head to the
/// classes seen so far — the paper's dense layer "output features' value
/// … is not static and changes during the operation" (§III-F-4).
pub trait Learner {
    /// One SGD step on a single sample (the paper trains at batch 1).
    /// Returns the loss.
    fn train_step(&mut self, x: &Tensor<f32>, label: usize, active_classes: usize, lr: f32)
        -> f32;

    /// One SGD step on a minibatch. Backends with a true batched
    /// datapath (the float `nn::Model`) override this with
    /// mean-gradient semantics; the default sequentially applies
    /// [`Learner::train_step`] per sample, so quantized/device backends
    /// keep the paper's per-sample behavior at any `--batch`. Returns
    /// the mean loss.
    fn train_batch(
        &mut self,
        xs: &[&Tensor<f32>],
        labels: &[usize],
        active_classes: usize,
        lr: f32,
    ) -> f32 {
        train_batch_sequential(self, xs, labels, active_classes, lr)
    }

    /// Predicted class among the first `active_classes`.
    fn predict(&mut self, x: &Tensor<f32>, active_classes: usize) -> usize;

    /// Batched prediction. Backends with a batched forward datapath
    /// (the float model, the Q4.12 fast engine) override this with one
    /// packed forward per minibatch — bit-identical per sample to
    /// [`Learner::predict`]; the default falls back to per-sample
    /// prediction, so accuracy sweeps never change results, only speed.
    fn predict_batch(&mut self, xs: &[&Tensor<f32>], active_classes: usize) -> Vec<usize> {
        xs.iter().map(|x| self.predict(x, active_classes)).collect()
    }

    /// Re-initialize parameters (GDumb's "dumb learner" trains from
    /// scratch for every query). Deterministic in `seed`.
    fn reinit(&mut self, seed: u64);

    /// Deepest cut point the backend supports for latent replay, or
    /// `None` when it has no cut datapath (the cycle-accurate device
    /// and the AOT XLA executable ship fixed full-network programs).
    /// Policies that need activations must check this before calling
    /// the methods below — like `clone_replica`, it is a runtime
    /// capability so `--policy latent-replay` can refuse an unsupported
    /// backend with an actionable error instead of a panic mid-run.
    fn max_latent_cut(&self) -> Option<usize> {
        None
    }

    /// Forward the frozen prefix of the network to `cut` for a batch of
    /// inputs (cut 0 returns the inputs unchanged). Only callable when
    /// [`Learner::max_latent_cut`] admits `cut`.
    fn forward_to_cut_batch(&mut self, _xs: &[&Tensor<f32>], _cut: usize) -> Vec<Tensor<f32>> {
        panic!("backend does not support latent replay (max_latent_cut() is None)")
    }

    /// One suffix-only training minibatch from stored activations at
    /// `cut`. Returns the mean loss. Only callable when
    /// [`Learner::max_latent_cut`] admits `cut`.
    fn train_latent_batch(
        &mut self,
        _acts: &[&Tensor<f32>],
        _labels: &[usize],
        _cut: usize,
        _active_classes: usize,
        _lr: f32,
    ) -> f32 {
        panic!("backend does not support latent replay (max_latent_cut() is None)")
    }

    /// Re-initialize only the trainable suffix from `cut`, leaving the
    /// frozen prefix untouched; at cut 0 this must match
    /// [`Learner::reinit`]. Only callable when
    /// [`Learner::max_latent_cut`] admits `cut`.
    fn reinit_suffix(&mut self, _cut: usize, _seed: u64) {
        panic!("backend does not support latent replay (max_latent_cut() is None)")
    }

    /// A bit-identical copy of this learner, used by the serving
    /// subsystem to populate a replica pool (`serve::Server` with
    /// `replicas > 1`) and to re-broadcast weights after each
    /// serve-while-learning train barrier. `None` means the backend
    /// cannot be duplicated (e.g. it owns device/runtime handles) and
    /// replicated serving must refuse it with an actionable error —
    /// which is why this is a runtime capability, not a `Clone` bound.
    fn clone_replica(&self) -> Option<Self>
    where
        Self: Sized,
    {
        None
    }

    /// Monotone weight-snapshot version, bumped by every weight update,
    /// or `None` when the backend has no version stamps. Versioned
    /// backends let the serving layer re-broadcast post-train weights
    /// as *diffs*: each replica copies only the tensors whose stamp
    /// advanced past its own ([`Learner::sync_weights_from`]), so a
    /// dense-head-only update ships one small tensor instead of the
    /// whole model.
    fn weights_version(&self) -> Option<u64> {
        None
    }

    /// Adopt `src`'s weights by diff, copying exactly the tensors whose
    /// version stamps differ (plus any update-order state that must
    /// travel with them, e.g. the quantized model's dither counter).
    /// Returns the bytes copied, or `None` when the backend does not
    /// support diff sync — the caller falls back to a full snapshot.
    /// Both learners must share snapshot lineage (replicas of one
    /// pool): stamps, not contents, decide what is copied.
    fn sync_weights_from(&mut self, src: &Self) -> Option<u64>
    where
        Self: Sized,
    {
        let _ = src;
        None
    }

    /// Bytes of one full weight snapshot — the re-broadcast baseline
    /// diff sync is measured against — or `None` when unknown.
    fn weights_bytes(&self) -> Option<u64> {
        None
    }

    // ---- Multi-task heads (PR 10) -----------------------------------
    //
    // A multi-task backend shares one backbone across K dense heads:
    // zero parameter growth outside the head itself. Single-head
    // backends keep the defaults below — task 0 is the only task and
    // routing degenerates to the plain batched predict.

    /// Number of task heads this backend serves (single-head backends: 1).
    fn num_tasks(&self) -> usize {
        1
    }

    /// Add a fresh dense head with `classes` outputs, deterministic in
    /// `seed`. Returns the new task id, or `None` when the backend
    /// ships a fixed single-head program (the cycle-accurate device,
    /// the AOT XLA executable) — like `clone_replica`, a runtime
    /// capability so multi-task serving can refuse an unsupported
    /// backend with an actionable error instead of a panic mid-run.
    fn add_task_head(&mut self, _classes: usize, _seed: u64) -> Option<usize> {
        None
    }

    /// Switch the active head. Task 0 always exists; switching to a
    /// missing head returns an actionable error (never panics or
    /// silently serves the wrong head).
    fn set_active_task(&mut self, task: usize) -> Result<(), String> {
        if task == 0 {
            Ok(())
        } else {
            Err(format!("backend has a single head; task {task} does not exist"))
        }
    }

    /// The task whose head is active.
    fn active_task(&self) -> usize {
        0
    }

    /// Freeze the shared backbone so training moves only the active
    /// head (the serve barrier's head-only diff case). Returns whether
    /// the backend honors the flag.
    fn set_freeze_backbone(&mut self, _freeze: bool) -> bool {
        false
    }

    /// Route a mixed-task batch: `tasks[i]` selects sample i's head,
    /// `actives[i]` masks its logits. The default groups samples by
    /// (task, mask), swaps each head in via [`Learner::set_active_task`]
    /// and delegates to [`Learner::predict_batch`], restoring the entry
    /// task ([`default_predict_batch_tasks`]) — correct for any backend;
    /// multi-task backends override with one shared backbone pass over
    /// the whole batch.
    fn predict_batch_tasks(
        &mut self,
        xs: &[&Tensor<f32>],
        tasks: &[usize],
        actives: &[usize],
    ) -> Vec<usize> {
        default_predict_batch_tasks(self, xs, tasks, actives)
    }

    /// Bytes of the *active* head — the entire per-task parameter
    /// growth — or `None` when the backend has no head accounting.
    fn head_bytes(&self) -> Option<u64> {
        None
    }
}

impl Learner for crate::nn::Model {
    fn train_step(
        &mut self,
        x: &Tensor<f32>,
        label: usize,
        active_classes: usize,
        lr: f32,
    ) -> f32 {
        crate::nn::Model::train_step(self, x, label, active_classes, lr).loss
    }

    fn train_batch(
        &mut self,
        xs: &[&Tensor<f32>],
        labels: &[usize],
        active_classes: usize,
        lr: f32,
    ) -> f32 {
        crate::nn::Model::train_batch(self, xs, labels, active_classes, lr).loss
    }

    fn predict(&mut self, x: &Tensor<f32>, active_classes: usize) -> usize {
        crate::nn::Model::predict(self, x, active_classes)
    }

    fn predict_batch(&mut self, xs: &[&Tensor<f32>], active_classes: usize) -> Vec<usize> {
        crate::nn::Model::forward_batch(self, xs)
            .iter()
            .map(|logits| crate::nn::loss::predict(logits, active_classes))
            .collect()
    }

    fn reinit(&mut self, seed: u64) {
        crate::nn::Model::reinit(self, seed);
    }

    fn max_latent_cut(&self) -> Option<usize> {
        Some(crate::nn::MAX_CUT)
    }

    fn forward_to_cut_batch(&mut self, xs: &[&Tensor<f32>], cut: usize) -> Vec<Tensor<f32>> {
        crate::nn::Model::forward_to_cut_batch(self, xs, cut)
    }

    fn train_latent_batch(
        &mut self,
        acts: &[&Tensor<f32>],
        labels: &[usize],
        cut: usize,
        active_classes: usize,
        lr: f32,
    ) -> f32 {
        crate::nn::Model::train_batch_from(self, cut, acts, labels, active_classes, lr).loss
    }

    fn reinit_suffix(&mut self, cut: usize, seed: u64) {
        crate::nn::Model::reinit_suffix(self, cut, seed);
    }

    fn clone_replica(&self) -> Option<Self> {
        // Replicas are weight-stable snapshots: pack the conv kernels
        // into microkernel tile order once here, so steady-state serving
        // never repacks per batch (`nn::gemm::PackedA`).
        let mut replica = self.clone();
        replica.pack_weights();
        Some(replica)
    }

    fn weights_version(&self) -> Option<u64> {
        Some(crate::nn::Model::weights_version(self))
    }

    fn sync_weights_from(&mut self, src: &Self) -> Option<u64> {
        Some(crate::nn::Model::sync_weights_from(self, src))
    }

    fn weights_bytes(&self) -> Option<u64> {
        Some(crate::nn::Model::weights_bytes(self))
    }

    fn num_tasks(&self) -> usize {
        crate::nn::Model::num_tasks(self)
    }

    fn add_task_head(&mut self, classes: usize, seed: u64) -> Option<usize> {
        Some(crate::nn::Model::add_task_head(self, classes, seed))
    }

    fn set_active_task(&mut self, task: usize) -> Result<(), String> {
        crate::nn::Model::set_active_task(self, task)
    }

    fn active_task(&self) -> usize {
        crate::nn::Model::active_task(self)
    }

    fn set_freeze_backbone(&mut self, freeze: bool) -> bool {
        crate::nn::Model::set_freeze_backbone(self, freeze);
        true
    }

    fn predict_batch_tasks(
        &mut self,
        xs: &[&Tensor<f32>],
        tasks: &[usize],
        actives: &[usize],
    ) -> Vec<usize> {
        crate::nn::Model::predict_batch_tasks(self, xs, tasks, actives)
    }

    fn head_bytes(&self) -> Option<u64> {
        Some(crate::nn::Model::head_bytes(self, crate::nn::Model::active_task(self)))
    }
}
