//! Latent replay: store *activations* at a network cut point instead of
//! raw inputs (Pellegrini/Ravaglia et al.; ROADMAP item 2).
//!
//! The paper's replay memory holds raw 32×32×3 samples (6.144 MB for
//! 1000 slots, §III-E). Freezing a prefix of the Conv-Conv-Dense model
//! lets the memory hold the activation at a chosen cut instead: each
//! stored sample then skips the frozen prefix on every training epoch,
//! trading memory bytes per slot against per-step latency and accuracy.
//! That memory–latency–accuracy frontier is what `tinycl replay-bench`
//! sweeps.
//!
//! Mechanics:
//! * **Admission** — each arriving sample is pushed through the frozen
//!   prefix *once* (batched, one packed GEMM set per chunk on the fast
//!   engines), quantized to Q4.12 (the memory's native width, §III-E),
//!   and offered to a byte-budgeted greedy class-balanced store.
//! * **Training** — the suffix from the cut re-initializes per task
//!   (GDumb's "dumb learner", on the trainable suffix only) and trains
//!   on shuffled minibatches of stored latents.
//! * **Parity** — at `--replay-cut 0` the "activation" is the raw input
//!   and the policy *is* GDumb: same admissions, same epoch shuffles,
//!   same re-init seeds, bit-identical on the Q4.12 backends (pinned by
//!   `tests/latent_parity.rs`).

use super::memory::{ReplayStore, Replayable, SamplerKind};
use super::policy::{epoch_seed, ClPolicy, RunConfig, EVAL_BATCH};
use super::stream::Task;
use super::Learner;
use crate::data::Dataset;
use crate::fixed::{vecops, Fx};
use crate::tensor::{Shape, Tensor};

/// One stored latent: a Q4.12 activation (or raw input, at cut 0) plus
/// its class label for balanced admission.
#[derive(Clone)]
pub struct LatentSlot {
    pub data: Vec<Fx>,
    pub label: usize,
}

impl Replayable for LatentSlot {
    fn label(&self) -> usize {
        self.label
    }

    /// 16-bit values, like the raw-sample store.
    fn bursts(&self) -> u64 {
        (self.data.len() as u64 * 16).div_ceil(128)
    }
}

/// A byte-budgeted, greedy class-balanced store of Q4.12 activations.
///
/// The budget is in *bytes*, not slots: slot size depends on the cut
/// geometry, which the policy only learns from the first activation it
/// sees. Capacity resolves lazily at that point —
/// `max(budget / slot_bytes, 1)` slots — so the same byte budget yields
/// different slot counts at different cuts (the frontier's x-axis).
pub struct LatentMemory {
    budget_bytes: u64,
    seed: u64,
    store: Option<ReplayStore<LatentSlot>>,
    slot_shape: Option<Shape>,
}

impl LatentMemory {
    pub fn new(budget_bytes: u64, seed: u64) -> LatentMemory {
        assert!(budget_bytes > 0, "latent memory budget must be positive");
        LatentMemory { budget_bytes, seed, store: None, slot_shape: None }
    }

    /// The configured budget in bytes.
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Bytes per stored slot (Q4.12 = 2 bytes/value); `None` before the
    /// first offer fixes the activation geometry.
    pub fn slot_bytes(&self) -> Option<u64> {
        self.slot_shape.as_ref().map(|s| s.numel() as u64 * 2)
    }

    /// Slot capacity; `None` before the first offer.
    pub fn capacity(&self) -> Option<usize> {
        self.store.as_ref().map(ReplayStore::capacity)
    }

    pub fn len(&self) -> usize {
        self.store.as_ref().map_or(0, ReplayStore::len)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently resident in the memory (exact: slots × slot size).
    pub fn stored_bytes(&self) -> u64 {
        self.slot_bytes().unwrap_or(0) * self.len() as u64
    }

    /// Cumulative `(read, write)` traffic in 128-bit bursts.
    pub fn traffic(&self) -> (u64, u64) {
        self.store.as_ref().map_or((0, 0), |s| (s.read_bursts, s.write_bursts))
    }

    /// Quantize one activation to Q4.12 and offer it to the balanced
    /// sampler. The first offer fixes the slot geometry and resolves the
    /// byte budget into a slot capacity; later offers must match.
    pub fn offer(&mut self, act: &Tensor<f32>, label: usize) -> bool {
        let shape = self.slot_shape.get_or_insert_with(|| act.shape().clone());
        assert_eq!(act.shape(), shape, "latent geometry changed between offers");
        let store = self.store.get_or_insert_with(|| {
            let slot_bytes = shape.numel() as u64 * 2;
            let capacity = ((self.budget_bytes / slot_bytes) as usize).max(1);
            ReplayStore::new(SamplerKind::GreedyBalanced, capacity, self.seed)
        });
        store.offer(&LatentSlot { data: vecops::quantize(act.data()), label })
    }

    /// One shuffled pass over the memory, pre-chunked into minibatches —
    /// same shuffle stream as the raw store, so a cut-0 run replays
    /// GDumb's exact epoch order.
    pub fn epoch_batches(&mut self, seed: u64, batch: usize) -> Vec<Vec<LatentSlot>> {
        match &mut self.store {
            Some(s) => s.epoch_batches(seed, batch),
            None => Vec::new(),
        }
    }

    /// Dequantize a stored slot back to the activation tensor the suffix
    /// trains on (exact: stored values live on the Fx grid).
    pub fn to_tensor(&self, slot: &LatentSlot) -> Tensor<f32> {
        let shape = self.slot_shape.clone().expect("empty memory has no geometry");
        Tensor::from_vec(shape, vecops::dequantize(&slot.data))
    }
}

/// The latent-replay policy: GDumb's greedy-balanced admission and
/// train-from-scratch loop, applied to the trainable suffix at
/// `--replay-cut` over stored activations.
pub struct LatentReplay {
    pub memory: LatentMemory,
    cut: usize,
    reinit_counter: u64,
}

impl LatentReplay {
    /// `budget_bytes` is the replay-memory byte budget (the paper's
    /// 6.144 MB memory is `--memory-bytes 6144000`); `cut` picks the
    /// frozen prefix (0 = none — plain GDumb).
    pub fn new(budget_bytes: u64, cut: usize, seed: u64) -> LatentReplay {
        assert!(
            cut <= crate::nn::MAX_CUT,
            "replay cut {cut} out of range (max {})",
            crate::nn::MAX_CUT
        );
        LatentReplay { memory: LatentMemory::new(budget_bytes, seed), cut, reinit_counter: 0 }
    }

    pub fn cut(&self) -> usize {
        self.cut
    }
}

impl ClPolicy for LatentReplay {
    fn name(&self) -> &'static str {
        "latent-replay"
    }

    fn observe_task(
        &mut self,
        learner: &mut dyn Learner,
        task: &Task,
        dataset: &Dataset,
        active_classes: usize,
        cfg: &RunConfig,
    ) -> u64 {
        // Admission: one frozen-prefix forward per arriving sample, in
        // stream order, chunked so the fast engines run one packed GEMM
        // set per chunk rather than per sample.
        for chunk in task.sample_indices.chunks(EVAL_BATCH) {
            let xs: Vec<&Tensor<f32>> = chunk.iter().map(|&i| &dataset.samples[i].x).collect();
            let acts = learner.forward_to_cut_batch(&xs, self.cut);
            for (act, &i) in acts.iter().zip(chunk) {
                self.memory.offer(act, dataset.samples[i].label);
            }
        }
        // Dumb learner on the suffix only: the frozen prefix keeps its
        // weights (stored latents would go stale otherwise), everything
        // from the cut re-initializes and trains from scratch. Same
        // seed schedule as GDumb, so cut 0 replays it exactly.
        self.reinit_counter += 1;
        learner.reinit_suffix(self.cut, cfg.seed ^ (self.reinit_counter << 32));
        let mut steps = 0;
        for epoch in 0..cfg.epochs {
            let es = epoch_seed(cfg.seed, task.id, epoch);
            for chunk in self.memory.epoch_batches(es, cfg.batch) {
                let acts: Vec<Tensor<f32>> =
                    chunk.iter().map(|s| self.memory.to_tensor(s)).collect();
                let refs: Vec<&Tensor<f32>> = acts.iter().collect();
                let labels: Vec<usize> = chunk.iter().map(|s| s.label).collect();
                learner.train_latent_batch(&refs, &labels, self.cut, active_classes, cfg.lr);
                steps += chunk.len() as u64;
            }
        }
        steps
    }

    fn replay_traffic(&self) -> (u64, u64) {
        self.memory.traffic()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_act(seed: u64, shape: Shape) -> Tensor<f32> {
        let mut rng = Pcg32::seeded(seed);
        let n = shape.numel();
        Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-4.0, 4.0)).collect())
    }

    #[test]
    fn q412_round_trip_is_tight_and_idempotent() {
        // Property: quantize→dequantize lands within half a Q4.12 step
        // of the original, and a second round trip is exact (the grid is
        // a fixed point of the codec).
        let step = 1.0 / 4096.0;
        for case in 0..50u64 {
            let act = rand_act(1000 + case, Shape::d3(2, 3, 3));
            let q = vecops::quantize(act.data());
            let d = vecops::dequantize(&q);
            for (orig, back) in act.data().iter().zip(&d) {
                assert!(
                    (orig - back).abs() <= 0.5 * step + f32::EPSILON,
                    "case {case}: {orig} -> {back}"
                );
            }
            assert_eq!(vecops::quantize(&d), q, "case {case}: grid not idempotent");
        }
    }

    #[test]
    fn round_trip_through_memory_is_exact_on_the_grid() {
        // Offer pre-quantized activations; what comes back out must be
        // bit-identical — the memory is a lossless store for anything
        // already on the Fx grid (raw Q4.12 inputs at cut 0, and every
        // activation the quantized datapath produces).
        let shape = Shape::d3(2, 4, 4);
        let mut mem = LatentMemory::new(10_000, 9);
        let mut originals = Vec::new();
        for i in 0..6u64 {
            let raw = rand_act(2000 + i, shape.clone());
            let snapped = Tensor::from_vec(
                shape.clone(),
                vecops::dequantize(&vecops::quantize(raw.data())),
            );
            assert!(mem.offer(&snapped, i as usize % 3), "under capacity, all admitted");
            originals.push(snapped);
        }
        let mut seen = 0;
        for chunk in mem.epoch_batches(7, 2) {
            for slot in &chunk {
                let t = mem.to_tensor(slot);
                let orig = originals
                    .iter()
                    .find(|o| o.data() == t.data())
                    .unwrap_or_else(|| panic!("slot does not round-trip to any original"));
                assert_eq!(orig.shape(), t.shape());
                seen += 1;
            }
        }
        assert_eq!(seen, 6);
    }

    #[test]
    fn byte_accounting_is_exact() {
        // Shape 2×4×4 = 32 values = 64 B/slot; an 8-slot budget of
        // 512 B resolves exactly, stored_bytes tracks slot count, and
        // burst metering charges ceil(32·16/128) = 4 bursts per write.
        let shape = Shape::d3(2, 4, 4);
        let mut mem = LatentMemory::new(512, 3);
        assert_eq!(mem.slot_bytes(), None, "geometry unknown before first offer");
        assert_eq!(mem.capacity(), None);
        for i in 0..12u64 {
            mem.offer(&rand_act(3000 + i, shape.clone()), 0);
        }
        assert_eq!(mem.slot_bytes(), Some(64));
        assert_eq!(mem.capacity(), Some(8), "512 B / 64 B per slot");
        assert_eq!(mem.len(), 8, "single class: fills to capacity, then rejects");
        assert_eq!(mem.stored_bytes(), 512);
        let (reads, writes) = mem.traffic();
        assert_eq!(writes, 8 * 4, "4 bursts per admitted slot");
        assert_eq!(reads, 0);
    }

    #[test]
    fn sub_slot_budget_still_holds_one_item() {
        let shape = Shape::d3(2, 4, 4); // 64 B/slot
        let mut mem = LatentMemory::new(10, 3);
        assert!(mem.offer(&rand_act(1, shape), 0));
        assert_eq!(mem.capacity(), Some(1));
        assert_eq!(mem.len(), 1);
    }

    #[test]
    #[should_panic(expected = "latent geometry changed")]
    fn geometry_change_between_offers_panics() {
        let mut mem = LatentMemory::new(10_000, 3);
        mem.offer(&rand_act(1, Shape::d3(2, 4, 4)), 0);
        mem.offer(&rand_act(2, Shape::d3(3, 4, 4)), 0);
    }

    #[test]
    fn admission_is_class_balanced() {
        // Same greedy sampler as GDumb: a skewed stream still ends
        // class-balanced within quota arithmetic.
        let shape = Shape::d3(2, 4, 4);
        let mut mem = LatentMemory::new(512, 5); // 8 slots
        for i in 0..40u64 {
            let label = if i < 30 { 0 } else { 1 };
            mem.offer(&rand_act(4000 + i, shape.clone()), label);
        }
        let store = mem.store.as_ref().unwrap();
        let counts = store.class_counts();
        assert_eq!(counts.get(&0), Some(&4));
        assert_eq!(counts.get(&1), Some(&4));
    }
}
