//! Class-incremental task streams (§IV-A: 5 tasks × 2 classes).

use crate::data::Dataset;
use crate::util::rng::Pcg32;

/// One task: a set of classes and the indices of its training samples.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: usize,
    pub classes: Vec<usize>,
    /// Indices into the stream's dataset, in arrival order.
    pub sample_indices: Vec<usize>,
}

/// A class-incremental split of a dataset into tasks.
#[derive(Clone, Debug)]
pub struct TaskStream {
    pub tasks: Vec<Task>,
    pub num_classes: usize,
}

impl TaskStream {
    /// Split `dataset` into `num_tasks` tasks of consecutive classes
    /// (task 0 = classes 0..k, task 1 = k..2k, …), shuffling each task's
    /// arrival order deterministically in `seed`.
    pub fn class_incremental(dataset: &Dataset, num_tasks: usize, seed: u64) -> TaskStream {
        assert!(num_tasks > 0 && dataset.num_classes % num_tasks == 0,
            "{} classes cannot split into {num_tasks} equal tasks", dataset.num_classes);
        let per_task = dataset.num_classes / num_tasks;
        let tasks = (0..num_tasks)
            .map(|id| {
                let classes: Vec<usize> = (id * per_task..(id + 1) * per_task).collect();
                let mut idx: Vec<usize> = classes
                    .iter()
                    .flat_map(|&c| dataset.class_indices(c).iter().copied())
                    .collect();
                let mut rng = Pcg32::new(seed, id as u64 + 1);
                rng.shuffle(&mut idx);
                Task { id, classes, sample_indices: idx }
            })
            .collect();
        TaskStream { tasks, num_classes: dataset.num_classes }
    }

    /// The paper's setup: 5 tasks × 2 classes.
    pub fn paper(dataset: &Dataset, seed: u64) -> TaskStream {
        TaskStream::class_incremental(dataset, 5, seed)
    }

    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Number of classes visible after finishing task `t` (inclusive) —
    /// the dense head's dynamic output size.
    pub fn active_classes_after(&self, t: usize) -> usize {
        self.tasks[..=t].iter().map(|task| task.classes.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticCifar;

    fn tiny_dataset() -> Dataset {
        SyntheticCifar { image_size: 8, ..Default::default() }.generate(6, 0)
    }

    #[test]
    fn paper_split_is_5x2() {
        let d = tiny_dataset();
        let s = TaskStream::paper(&d, 1);
        assert_eq!(s.num_tasks(), 5);
        for (i, t) in s.tasks.iter().enumerate() {
            assert_eq!(t.classes, vec![2 * i, 2 * i + 1]);
            assert_eq!(t.sample_indices.len(), 12);
        }
        assert_eq!(s.active_classes_after(0), 2);
        assert_eq!(s.active_classes_after(4), 10);
    }

    #[test]
    fn tasks_partition_the_dataset() {
        let d = tiny_dataset();
        let s = TaskStream::paper(&d, 1);
        let mut seen: Vec<usize> = s.tasks.iter().flat_map(|t| t.sample_indices.clone()).collect();
        seen.sort_unstable();
        let expect: Vec<usize> = (0..d.len()).collect();
        assert_eq!(seen, expect);
    }

    #[test]
    fn samples_match_their_task_classes() {
        let d = tiny_dataset();
        let s = TaskStream::class_incremental(&d, 2, 3);
        for t in &s.tasks {
            for &i in &t.sample_indices {
                assert!(t.classes.contains(&d.samples[i].label));
            }
        }
    }

    #[test]
    fn shuffle_depends_on_seed_only() {
        let d = tiny_dataset();
        let a = TaskStream::paper(&d, 7);
        let b = TaskStream::paper(&d, 7);
        let c = TaskStream::paper(&d, 8);
        assert_eq!(a.tasks[0].sample_indices, b.tasks[0].sample_indices);
        assert_ne!(a.tasks[0].sample_indices, c.tasks[0].sample_indices);
    }

    #[test]
    #[should_panic(expected = "cannot split")]
    fn uneven_split_rejected() {
        let d = tiny_dataset();
        let _ = TaskStream::class_incremental(&d, 3, 0);
    }
}
