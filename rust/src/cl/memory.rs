//! The replay memory — the paper's "Training Data Memory" (§III-E):
//! a fixed budget of stored samples, kept class-balanced ("the cardinality
//! of each training sample set must be equal, thus we avoid class
//! imbalance problems"), updated "by replacing some samples of old classes
//! with more samples of new classes".
//!
//! Two samplers:
//! * [`SamplerKind::GreedyBalanced`] — GDumb's sampler [24]: admit until
//!   the per-class quota is full; when a new class appears the quota
//!   shrinks and the most-represented classes evict (deterministically,
//!   oldest first).
//! * [`SamplerKind::Reservoir`] — classic reservoir sampling used by
//!   Experience Replay [21].
//!
//! The store is generic over what it holds ([`Replayable`]): raw samples
//! for GDumb/ER ([`ReplayMemory`]) and quantized cut-point activations for
//! latent replay (`cl::latent`). It also meters its own off-chip traffic
//! in 128-bit bursts so the energy model can charge sample movement (the
//! 6.144 MB store lives off-die; see DESIGN.md).

use std::collections::{BTreeMap, VecDeque};

use crate::data::Sample;
use crate::util::rng::Pcg32;

/// Eviction/admission strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    GreedyBalanced,
    Reservoir,
}

/// Anything the replay store can hold: it has a class label (for balanced
/// admission) and a movement cost in 128-bit off-chip bursts.
pub trait Replayable: Clone {
    fn label(&self) -> usize;
    /// 128-bit bursts needed to move this item off/on chip.
    fn bursts(&self) -> u64;
}

impl Replayable for Sample {
    fn label(&self) -> usize {
        self.label
    }

    /// CHW 16-bit values.
    fn bursts(&self) -> u64 {
        (self.x.shape().numel() as u64 * 16).div_ceil(128)
    }
}

/// A budgeted item store.
pub struct ReplayStore<T: Replayable> {
    kind: SamplerKind,
    capacity: usize,
    slots: Vec<T>,
    /// Total items offered via [`Self::offer`] (reservoir denominator).
    seen: u64,
    rng: Pcg32,
    /// Off-chip write traffic, 128-bit bursts.
    pub write_bursts: u64,
    /// Off-chip read traffic, 128-bit bursts.
    pub read_bursts: u64,
    // Greedy-sampler bookkeeping, maintained incrementally so an offer is
    // O(log n) instead of rebuilding counts + scanning slots per offer
    // (O(n²) per task at the paper's 1000-slot memory). Unused (and not
    // maintained) by the reservoir sampler.
    /// Stored items per class.
    counts: BTreeMap<usize, usize>,
    /// Arrival order per class (front = oldest = next eviction victim).
    fifo: BTreeMap<usize, VecDeque<u64>>,
    /// Arrival sequence number of each slot, aligned with `slots` and
    /// always ascending (appends grow it, removals preserve order).
    order: Vec<u64>,
    next_seq: u64,
}

/// The raw-sample store used by GDumb and Experience Replay.
pub type ReplayMemory = ReplayStore<Sample>;

impl<T: Replayable> ReplayStore<T> {
    pub fn new(kind: SamplerKind, capacity: usize, seed: u64) -> ReplayStore<T> {
        assert!(capacity > 0);
        ReplayStore {
            kind,
            capacity,
            slots: Vec::with_capacity(capacity),
            seen: 0,
            rng: Pcg32::new(seed, 0xC1),
            write_bursts: 0,
            read_bursts: 0,
            counts: BTreeMap::new(),
            fifo: BTreeMap::new(),
            order: Vec::new(),
            next_seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn samples(&self) -> &[T] {
        &self.slots
    }

    /// Count of stored items per class label.
    pub fn class_counts(&self) -> BTreeMap<usize, usize> {
        let mut m = BTreeMap::new();
        for s in &self.slots {
            *m.entry(s.label()).or_insert(0) += 1;
        }
        m
    }

    /// Offer one stream item to the memory; it is stored or dropped
    /// according to the sampler. Returns `true` if stored.
    pub fn offer(&mut self, item: &T) -> bool {
        self.seen += 1;
        match self.kind {
            SamplerKind::GreedyBalanced => self.offer_greedy(item),
            SamplerKind::Reservoir => self.offer_reservoir(item),
        }
    }

    /// GDumb Alg. 1: admit if below capacity or if this class holds fewer
    /// than the (shrinking) per-class quota; evict the oldest item of the
    /// most-represented class (ties break to the largest label, matching
    /// `BTreeMap` iteration order).
    fn offer_greedy(&mut self, item: &T) -> bool {
        debug_assert_eq!(self.counts, self.class_counts());
        let label = item.label();
        let num_classes = self.counts.len() + usize::from(!self.counts.contains_key(&label));
        let quota = self.capacity / num_classes.max(1);
        let mine = self.counts.get(&label).copied().unwrap_or(0);

        if self.slots.len() < self.capacity {
            self.store(item.clone());
            return true;
        }
        if mine >= quota {
            return false;
        }
        let (&victim, _) = self.counts.iter().max_by_key(|&(_, n)| *n).unwrap();
        let seq = self.fifo.get_mut(&victim).unwrap().pop_front().unwrap();
        let pos = self.order.binary_search(&seq).unwrap();
        self.slots.remove(pos);
        self.order.remove(pos);
        let c = self.counts.get_mut(&victim).unwrap();
        *c -= 1;
        if *c == 0 {
            self.counts.remove(&victim);
            self.fifo.remove(&victim);
        }
        self.store(item.clone());
        true
    }

    fn offer_reservoir(&mut self, item: &T) -> bool {
        if self.slots.len() < self.capacity {
            self.store(item.clone());
            return true;
        }
        let j = self.rng.below_u64(self.seen) as usize;
        if j < self.capacity {
            self.write_bursts += item.bursts();
            self.slots[j] = item.clone();
            true
        } else {
            false
        }
    }

    fn store(&mut self, item: T) {
        self.write_bursts += item.bursts();
        if self.kind == SamplerKind::GreedyBalanced {
            let label = item.label();
            *self.counts.entry(label).or_insert(0) += 1;
            self.fifo.entry(label).or_default().push_back(self.next_seq);
            self.order.push(self.next_seq);
            self.next_seq += 1;
        }
        self.slots.push(item);
    }

    /// Read the whole memory in a shuffled order (one GDumb training
    /// epoch), charging read traffic.
    pub fn epoch(&mut self, seed: u64) -> Vec<T> {
        let mut order: Vec<usize> = (0..self.slots.len()).collect();
        let mut rng = Pcg32::new(seed, 0xE0);
        rng.shuffle(&mut order);
        let out: Vec<T> = order.iter().map(|&i| self.slots[i].clone()).collect();
        self.read_bursts += out.iter().map(Replayable::bursts).sum::<u64>();
        out
    }

    /// One shuffled pass over the memory pre-chunked into training
    /// minibatches of `batch` items (the last one may be short), in
    /// the same order [`Self::epoch`] would yield for this seed.
    /// Charges the same read traffic; each item is cloned exactly
    /// once (the chunks are split off the epoch's Vec, not re-cloned).
    pub fn epoch_batches(&mut self, seed: u64, batch: usize) -> Vec<Vec<T>> {
        let samples = self.epoch(seed);
        let batch = batch.max(1);
        let mut out: Vec<Vec<T>> = Vec::with_capacity(samples.len().div_ceil(batch));
        for s in samples {
            match out.last_mut() {
                Some(last) if last.len() < batch => last.push(s),
                _ => {
                    let mut chunk = Vec::with_capacity(batch);
                    chunk.push(s);
                    out.push(chunk);
                }
            }
        }
        out
    }

    /// Draw `k` random stored items (ER's replay draw), charging reads.
    pub fn draw(&mut self, k: usize) -> Vec<T> {
        let k = k.min(self.slots.len());
        let idx = self.rng.sample_indices(self.slots.len(), k);
        let out: Vec<T> = idx.iter().map(|&i| self.slots[i].clone()).collect();
        self.read_bursts += out.iter().map(Replayable::bursts).sum::<u64>();
        out
    }
}

impl ReplayMemory {
    /// The paper's memory: 6.144 MB = 1000 samples of 32×32 RGB at 16 bit.
    pub fn paper(kind: SamplerKind, seed: u64) -> ReplayMemory {
        ReplayMemory::new(kind, 1000, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Shape, Tensor};
    use crate::util::proptest;

    fn sample(label: usize, tag: f32) -> Sample {
        Sample { x: Tensor::from_vec(Shape::d3(1, 2, 2), vec![tag; 4]), label }
    }

    #[test]
    fn greedy_fills_to_capacity() {
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 10, 1);
        for i in 0..10 {
            assert!(m.offer(&sample(0, i as f32)));
        }
        assert_eq!(m.len(), 10);
    }

    #[test]
    fn greedy_rebalances_on_new_class() {
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 10, 1);
        for i in 0..10 {
            m.offer(&sample(0, i as f32));
        }
        // New class arrives: quota becomes 5 per class; class-1 samples
        // must displace class-0 ones.
        for i in 0..5 {
            assert!(m.offer(&sample(1, 100.0 + i as f32)), "class 1 sample {i} rejected");
        }
        let counts = m.class_counts();
        assert_eq!(counts[&0], 5);
        assert_eq!(counts[&1], 5);
        // Quota reached: further class-1 samples rejected.
        assert!(!m.offer(&sample(1, 999.0)));
    }

    #[test]
    fn greedy_balanced_across_paper_stream() {
        // 5 tasks × 2 classes arriving sequentially: final memory must be
        // near-perfectly balanced (paper: "cardinality … must be equal").
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 100, 2);
        for class in 0..10 {
            for i in 0..50 {
                m.offer(&sample(class, i as f32));
            }
        }
        let counts = m.class_counts();
        assert_eq!(counts.len(), 10);
        for (&c, &n) in &counts {
            assert_eq!(n, 10, "class {c} has {n} ≠ 10");
        }
    }

    /// The pre-refactor greedy sampler, kept verbatim as a reference model:
    /// rebuild `class_counts()` per offer, evict via an O(n) position scan.
    /// The incremental sampler must make identical decisions and keep the
    /// slots in an identical order.
    struct ReferenceGreedy {
        capacity: usize,
        slots: Vec<(usize, f32)>,
    }

    impl ReferenceGreedy {
        fn offer(&mut self, label: usize, tag: f32) -> bool {
            let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
            for &(l, _) in &self.slots {
                *counts.entry(l).or_insert(0) += 1;
            }
            let num_classes = counts.len() + usize::from(!counts.contains_key(&label));
            let quota = self.capacity / num_classes.max(1);
            let mine = counts.get(&label).copied().unwrap_or(0);
            if self.slots.len() < self.capacity {
                self.slots.push((label, tag));
                return true;
            }
            if mine >= quota {
                return false;
            }
            let (&victim, _) = counts.iter().max_by_key(|&(_, n)| *n).unwrap();
            if let Some(pos) = self.slots.iter().position(|&(l, _)| l == victim) {
                self.slots.remove(pos);
            }
            self.slots.push((label, tag));
            true
        }
    }

    #[test]
    fn greedy_matches_reference_on_random_streams() {
        proptest::check("greedy old-vs-new parity", 0xCAFE, 60, |g| {
            let capacity = g.usize_in(1, 24);
            let classes = g.usize_in(1, 8);
            let offers = g.usize_in(1, 160);
            let mut new = ReplayMemory::new(SamplerKind::GreedyBalanced, capacity, 7);
            let mut old = ReferenceGreedy { capacity, slots: Vec::new() };
            for t in 0..offers {
                let label = g.usize_in(0, classes - 1);
                let tag = t as f32;
                let a = new.offer(&sample(label, tag));
                let b = old.offer(label, tag);
                assert_eq!(a, b, "admit decision diverged at offer {t}");
                let got: Vec<(usize, f32)> =
                    new.samples().iter().map(|s| (s.label, s.x.data()[0])).collect();
                assert_eq!(got, old.slots, "stored sequence diverged at offer {t}");
            }
        });
    }

    #[test]
    fn greedy_invariants_under_random_streams() {
        proptest::check("greedy invariants", 0xBEEF, 60, |g| {
            let capacity = g.usize_in(1, 32);
            let classes = g.usize_in(1, 10);
            let offers = g.usize_in(1, 200);
            let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, capacity, 11);
            let mut last_writes = 0;
            for t in 0..offers {
                let label = g.usize_in(0, classes - 1);
                let before = m.class_counts();
                let was_full = m.len() == capacity;
                let stored = m.offer(&sample(label, t as f32));
                assert!(m.len() <= capacity);
                let after = m.class_counts();
                assert_eq!(after.values().sum::<usize>(), m.len());
                // Balance bounds once the memory is full: an admitted
                // class never exceeds the (shrinking) per-class quota, a
                // rejected class was already at it, and rebalancing only
                // ever shrinks the most-represented class.
                if was_full {
                    let nc = before.len() + usize::from(!before.contains_key(&label));
                    let quota = capacity / nc.max(1);
                    let mine = before.get(&label).copied().unwrap_or(0);
                    if stored {
                        assert!(after[&label] <= quota, "offer {t}: over quota");
                    } else {
                        assert!(mine >= quota, "offer {t}: rejected below quota");
                    }
                    let max_before = before.values().max().copied().unwrap_or(0);
                    let max_after = after.values().max().copied().unwrap_or(0);
                    assert!(max_after <= max_before, "offer {t}: imbalance grew");
                }
                // Burst accounting: monotone, charged exactly on store.
                let expected = if stored { last_writes + 1 } else { last_writes };
                assert_eq!(m.write_bursts, expected, "write bursts at offer {t}");
                last_writes = m.write_bursts;
            }
        });
    }

    #[test]
    fn reservoir_invariants_under_random_streams() {
        proptest::check("reservoir invariants", 0xF00D, 40, |g| {
            let capacity = g.usize_in(1, 24);
            let offers = g.usize_in(1, 200);
            let mut m = ReplayMemory::new(SamplerKind::Reservoir, capacity, 13);
            let mut last_writes = 0;
            for t in 0..offers {
                let stored = m.offer(&sample(t % 5, t as f32));
                assert!(m.len() <= capacity);
                assert_eq!(m.len(), capacity.min(t + 1), "size cap at offer {t}");
                let expected = if stored { last_writes + 1 } else { last_writes };
                assert_eq!(m.write_bursts, expected, "write bursts at offer {t}");
                last_writes = m.write_bursts;
            }
        });
    }

    #[test]
    fn reservoir_inclusion_is_uniform_across_seeds() {
        // Algorithm R keeps every stream item with probability
        // capacity/seen — including the early ones that filled the
        // reservoir. The old `next_u64() % seen` draw was modulo-biased;
        // the Lemire draw must keep per-item inclusion flat. 400 seeds,
        // capacity 10, stream 50 → expected inclusion 400·0.2 = 80,
        // σ = √(400·0.2·0.8) = 8; bound at 5σ.
        const SEEDS: u64 = 400;
        const CAP: usize = 10;
        const STREAM: usize = 50;
        let mut included = [0u32; STREAM];
        for seed in 0..SEEDS {
            let mut m = ReplayMemory::new(SamplerKind::Reservoir, CAP, seed);
            for t in 0..STREAM {
                m.offer(&sample(0, t as f32));
            }
            assert_eq!(m.len(), CAP);
            for s in m.samples() {
                included[s.x.data()[0] as usize] += 1;
            }
        }
        let expected = SEEDS as f64 * CAP as f64 / STREAM as f64;
        let sigma = (SEEDS as f64 * 0.2 * 0.8).sqrt();
        for (i, &n) in included.iter().enumerate() {
            assert!(
                (n as f64 - expected).abs() <= 5.0 * sigma,
                "item {i} included {n} times, expected {expected}±{:.0}",
                5.0 * sigma
            );
        }
        let total: u32 = included.iter().sum();
        assert_eq!(total as usize, SEEDS as usize * CAP, "reservoir always holds CAP items");
    }

    #[test]
    fn reservoir_keeps_capacity_and_mixes() {
        let mut m = ReplayMemory::new(SamplerKind::Reservoir, 50, 3);
        for class in 0..5 {
            for i in 0..100 {
                m.offer(&sample(class, i as f32));
            }
        }
        assert_eq!(m.len(), 50);
        // Every class should retain some representation w.h.p.
        let counts = m.class_counts();
        assert!(counts.len() >= 4, "reservoir collapsed: {counts:?}");
    }

    #[test]
    fn traffic_metered() {
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 4, 4);
        for i in 0..4 {
            m.offer(&sample(0, i as f32));
        }
        // 4 values × 16 b = 64 b → 1 burst per sample.
        assert_eq!(m.write_bursts, 4);
        let _ = m.epoch(0);
        assert_eq!(m.read_bursts, 4);
        let _ = m.draw(2);
        assert_eq!(m.read_bursts, 6);
    }

    #[test]
    fn epoch_is_a_permutation() {
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 8, 5);
        for i in 0..8 {
            m.offer(&sample(i % 2, i as f32));
        }
        let e = m.epoch(9);
        assert_eq!(e.len(), 8);
        let mut tags: Vec<i32> = e.iter().map(|s| s.x.data()[0] as i32).collect();
        tags.sort_unstable();
        assert_eq!(tags, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn epoch_batches_partition_the_epoch() {
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 10, 6);
        for i in 0..10 {
            m.offer(&sample(i % 3, i as f32));
        }
        let batches = m.epoch_batches(4, 4);
        assert_eq!(batches.len(), 3, "10 samples in batches of 4 → 4+4+2");
        assert_eq!(batches[0].len(), 4);
        assert_eq!(batches[2].len(), 2);
        // Same shuffle as a plain epoch at the same seed.
        let flat: Vec<i32> = batches.iter().flatten().map(|s| s.x.data()[0] as i32).collect();
        let plain: Vec<i32> = m.epoch(4).iter().map(|s| s.x.data()[0] as i32).collect();
        assert_eq!(flat, plain);
    }

    #[test]
    fn paper_capacity_is_1000() {
        let m = ReplayMemory::paper(SamplerKind::GreedyBalanced, 0);
        assert_eq!(m.capacity(), 1000);
        // 6.144 MB / (32×32×3 × 2 B) = 1000 exactly.
        assert_eq!(6_144_000 / (32 * 32 * 3 * 2), 1000);
    }
}
