//! The replay memory — the paper's "Training Data Memory" (§III-E):
//! a fixed budget of stored samples, kept class-balanced ("the cardinality
//! of each training sample set must be equal, thus we avoid class
//! imbalance problems"), updated "by replacing some samples of old classes
//! with more samples of new classes".
//!
//! Two samplers:
//! * [`SamplerKind::GreedyBalanced`] — GDumb's sampler [24]: admit until
//!   the per-class quota is full; when a new class appears the quota
//!   shrinks and the most-represented classes evict (deterministically,
//!   oldest first).
//! * [`SamplerKind::Reservoir`] — classic reservoir sampling used by
//!   Experience Replay [21].
//!
//! The memory also meters its own off-chip traffic in 128-bit bursts so
//! the energy model can charge GDumb sample movement (the 6.144 MB store
//! lives off-die; see DESIGN.md).

use crate::data::Sample;
use crate::util::rng::Pcg32;

/// Eviction/admission strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    GreedyBalanced,
    Reservoir,
}

/// A budgeted sample store.
pub struct ReplayMemory {
    kind: SamplerKind,
    capacity: usize,
    slots: Vec<Sample>,
    /// Total samples offered via [`Self::offer`] (reservoir denominator).
    seen: u64,
    rng: Pcg32,
    /// Off-chip write traffic, 128-bit bursts.
    pub write_bursts: u64,
    /// Off-chip read traffic, 128-bit bursts.
    pub read_bursts: u64,
}

impl ReplayMemory {
    pub fn new(kind: SamplerKind, capacity: usize, seed: u64) -> ReplayMemory {
        assert!(capacity > 0);
        ReplayMemory {
            kind,
            capacity,
            slots: Vec::with_capacity(capacity),
            seen: 0,
            rng: Pcg32::new(seed, 0xC1),
            write_bursts: 0,
            read_bursts: 0,
        }
    }

    /// The paper's memory: 6.144 MB = 1000 samples of 32×32 RGB at 16 bit.
    pub fn paper(kind: SamplerKind, seed: u64) -> ReplayMemory {
        ReplayMemory::new(kind, 1000, seed)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn samples(&self) -> &[Sample] {
        &self.slots
    }

    /// 128-bit bursts needed to move one sample (CHW 16-bit values).
    fn bursts_per_sample(s: &Sample) -> u64 {
        (s.x.shape().numel() as u64 * 16).div_ceil(128)
    }

    /// Count of stored samples per class label.
    pub fn class_counts(&self) -> std::collections::BTreeMap<usize, usize> {
        let mut m = std::collections::BTreeMap::new();
        for s in &self.slots {
            *m.entry(s.label).or_insert(0) += 1;
        }
        m
    }

    /// Offer one stream sample to the memory; it is stored or dropped
    /// according to the sampler. Returns `true` if stored.
    pub fn offer(&mut self, sample: &Sample) -> bool {
        self.seen += 1;
        match self.kind {
            SamplerKind::GreedyBalanced => self.offer_greedy(sample),
            SamplerKind::Reservoir => self.offer_reservoir(sample),
        }
    }

    /// GDumb Alg. 1: admit if below capacity or if this class holds fewer
    /// than the (shrinking) per-class quota; evict from the largest class.
    fn offer_greedy(&mut self, sample: &Sample) -> bool {
        let counts = self.class_counts();
        let num_classes = counts.len() + usize::from(!counts.contains_key(&sample.label));
        let quota = self.capacity / num_classes.max(1);
        let mine = counts.get(&sample.label).copied().unwrap_or(0);

        if self.slots.len() < self.capacity {
            self.store(sample.clone());
            return true;
        }
        if mine >= quota {
            return false;
        }
        // Evict the oldest sample of the most-represented class.
        let (&victim_class, _) = counts.iter().max_by_key(|&(_, n)| *n).unwrap();
        if let Some(pos) = self.slots.iter().position(|s| s.label == victim_class) {
            self.slots.remove(pos);
        }
        self.store(sample.clone());
        true
    }

    fn offer_reservoir(&mut self, sample: &Sample) -> bool {
        if self.slots.len() < self.capacity {
            self.store(sample.clone());
            return true;
        }
        let j = (self.rng.next_u64() % self.seen) as usize;
        if j < self.capacity {
            self.write_bursts += Self::bursts_per_sample(sample);
            self.slots[j] = sample.clone();
            true
        } else {
            false
        }
    }

    fn store(&mut self, sample: Sample) {
        self.write_bursts += Self::bursts_per_sample(&sample);
        self.slots.push(sample);
    }

    /// Read the whole memory in a shuffled order (one GDumb training
    /// epoch), charging read traffic.
    pub fn epoch(&mut self, seed: u64) -> Vec<Sample> {
        let mut order: Vec<usize> = (0..self.slots.len()).collect();
        let mut rng = Pcg32::new(seed, 0xE0);
        rng.shuffle(&mut order);
        let out: Vec<Sample> = order.iter().map(|&i| self.slots[i].clone()).collect();
        self.read_bursts += out.iter().map(Self::bursts_per_sample).sum::<u64>();
        out
    }

    /// One shuffled pass over the memory pre-chunked into training
    /// minibatches of `batch` samples (the last one may be short), in
    /// the same order [`Self::epoch`] would yield for this seed.
    /// Charges the same read traffic; each sample is cloned exactly
    /// once (the chunks are split off the epoch's Vec, not re-cloned).
    pub fn epoch_batches(&mut self, seed: u64, batch: usize) -> Vec<Vec<Sample>> {
        let samples = self.epoch(seed);
        let batch = batch.max(1);
        let mut out: Vec<Vec<Sample>> = Vec::with_capacity(samples.len().div_ceil(batch));
        for s in samples {
            match out.last_mut() {
                Some(last) if last.len() < batch => last.push(s),
                _ => {
                    let mut chunk = Vec::with_capacity(batch);
                    chunk.push(s);
                    out.push(chunk);
                }
            }
        }
        out
    }

    /// Draw `k` random stored samples (ER's replay draw), charging reads.
    pub fn draw(&mut self, k: usize) -> Vec<Sample> {
        let k = k.min(self.slots.len());
        let idx = self.rng.sample_indices(self.slots.len(), k);
        let out: Vec<Sample> = idx.iter().map(|&i| self.slots[i].clone()).collect();
        self.read_bursts += out.iter().map(Self::bursts_per_sample).sum::<u64>();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Shape, Tensor};

    fn sample(label: usize, tag: f32) -> Sample {
        Sample { x: Tensor::from_vec(Shape::d3(1, 2, 2), vec![tag; 4]), label }
    }

    #[test]
    fn greedy_fills_to_capacity() {
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 10, 1);
        for i in 0..10 {
            assert!(m.offer(&sample(0, i as f32)));
        }
        assert_eq!(m.len(), 10);
    }

    #[test]
    fn greedy_rebalances_on_new_class() {
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 10, 1);
        for i in 0..10 {
            m.offer(&sample(0, i as f32));
        }
        // New class arrives: quota becomes 5 per class; class-1 samples
        // must displace class-0 ones.
        for i in 0..5 {
            assert!(m.offer(&sample(1, 100.0 + i as f32)), "class 1 sample {i} rejected");
        }
        let counts = m.class_counts();
        assert_eq!(counts[&0], 5);
        assert_eq!(counts[&1], 5);
        // Quota reached: further class-1 samples rejected.
        assert!(!m.offer(&sample(1, 999.0)));
    }

    #[test]
    fn greedy_balanced_across_paper_stream() {
        // 5 tasks × 2 classes arriving sequentially: final memory must be
        // near-perfectly balanced (paper: "cardinality … must be equal").
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 100, 2);
        for class in 0..10 {
            for i in 0..50 {
                m.offer(&sample(class, i as f32));
            }
        }
        let counts = m.class_counts();
        assert_eq!(counts.len(), 10);
        for (&c, &n) in &counts {
            assert_eq!(n, 10, "class {c} has {n} ≠ 10");
        }
    }

    #[test]
    fn reservoir_keeps_capacity_and_mixes() {
        let mut m = ReplayMemory::new(SamplerKind::Reservoir, 50, 3);
        for class in 0..5 {
            for i in 0..100 {
                m.offer(&sample(class, i as f32));
            }
        }
        assert_eq!(m.len(), 50);
        // Every class should retain some representation w.h.p.
        let counts = m.class_counts();
        assert!(counts.len() >= 4, "reservoir collapsed: {counts:?}");
    }

    #[test]
    fn traffic_metered() {
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 4, 4);
        for i in 0..4 {
            m.offer(&sample(0, i as f32));
        }
        // 4 values × 16 b = 64 b → 1 burst per sample.
        assert_eq!(m.write_bursts, 4);
        let _ = m.epoch(0);
        assert_eq!(m.read_bursts, 4);
        let _ = m.draw(2);
        assert_eq!(m.read_bursts, 6);
    }

    #[test]
    fn epoch_is_a_permutation() {
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 8, 5);
        for i in 0..8 {
            m.offer(&sample(i % 2, i as f32));
        }
        let e = m.epoch(9);
        assert_eq!(e.len(), 8);
        let mut tags: Vec<i32> = e.iter().map(|s| s.x.data()[0] as i32).collect();
        tags.sort_unstable();
        assert_eq!(tags, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn epoch_batches_partition_the_epoch() {
        let mut m = ReplayMemory::new(SamplerKind::GreedyBalanced, 10, 6);
        for i in 0..10 {
            m.offer(&sample(i % 3, i as f32));
        }
        let batches = m.epoch_batches(4, 4);
        assert_eq!(batches.len(), 3, "10 samples in batches of 4 → 4+4+2");
        assert_eq!(batches[0].len(), 4);
        assert_eq!(batches[2].len(), 2);
        // Same shuffle as a plain epoch at the same seed.
        let flat: Vec<i32> = batches.iter().flatten().map(|s| s.x.data()[0] as i32).collect();
        let plain: Vec<i32> = m.epoch(4).iter().map(|s| s.x.data()[0] as i32).collect();
        assert_eq!(flat, plain);
    }

    #[test]
    fn paper_capacity_is_1000() {
        let m = ReplayMemory::paper(SamplerKind::GreedyBalanced, 0);
        assert_eq!(m.capacity(), 1000);
        // 6.144 MB / (32×32×3 × 2 B) = 1000 exactly.
        assert_eq!(6_144_000 / (32 * 32 * 3 * 2), 1000);
    }
}
