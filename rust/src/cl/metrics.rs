//! Standard continual-learning metrics over the task-accuracy matrix.
//!
//! `R[i][j]` = accuracy on task `j`'s test set after finishing training
//! task `i`. From it: average final accuracy, backward transfer (BWT,
//! Lopez-Paz & Ranzato [18]) and the forgetting measure (Chaudhry et
//! al. [19]) — the quantities CF-avoidance policies are judged on.

use std::fmt;

/// Lower-triangular accuracy matrix filled task by task.
#[derive(Clone, Debug)]
pub struct AccuracyMatrix {
    /// `r[i][j]` for `j <= i`.
    r: Vec<Vec<f64>>,
    num_tasks: usize,
}

impl AccuracyMatrix {
    pub fn new(num_tasks: usize) -> AccuracyMatrix {
        AccuracyMatrix { r: Vec::with_capacity(num_tasks), num_tasks }
    }

    /// Record the accuracy row after finishing task `i`: one entry per
    /// task `0..=i`.
    pub fn push_row(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.r.len() + 1, "row must cover tasks 0..=i");
        assert!(self.r.len() < self.num_tasks, "matrix already complete");
        assert!(row.iter().all(|a| (0.0..=1.0).contains(a)));
        self.r.push(row);
    }

    pub fn rows_filled(&self) -> usize {
        self.r.len()
    }

    pub fn at(&self, after_task: usize, on_task: usize) -> f64 {
        self.r[after_task][on_task]
    }

    /// Average accuracy over all seen tasks after the last trained task.
    pub fn final_average(&self) -> f64 {
        let last = self.r.last().expect("empty matrix");
        last.iter().sum::<f64>() / last.len() as f64
    }

    /// Backward transfer: mean over tasks j < T of `R[T][j] − R[j][j]`.
    /// Negative BWT = forgetting.
    pub fn backward_transfer(&self) -> f64 {
        let t = self.r.len();
        if t < 2 {
            return 0.0;
        }
        let last = &self.r[t - 1];
        let sum: f64 = (0..t - 1).map(|j| last[j] - self.r[j][j]).sum();
        sum / (t - 1) as f64
    }

    /// Forgetting measure: mean over tasks j < T of
    /// `max_{i<T} R[i][j] − R[T][j]` (always ≥ 0 up to noise).
    pub fn forgetting(&self) -> f64 {
        let t = self.r.len();
        if t < 2 {
            return 0.0;
        }
        let last = &self.r[t - 1];
        let sum: f64 = (0..t - 1)
            .map(|j| {
                let best = (j..t - 1).map(|i| self.r[i][j]).fold(f64::MIN, f64::max);
                best - last[j]
            })
            .sum();
        sum / (t - 1) as f64
    }
}

impl fmt::Display for AccuracyMatrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:>8}", "after\\on")?;
        for j in 0..self.r.len() {
            write!(f, " {:>6}", format!("T{j}"))?;
        }
        writeln!(f)?;
        for (i, row) in self.r.iter().enumerate() {
            write!(f, "{:>8}", format!("T{i}"))?;
            for a in row {
                write!(f, " {:>6.3}", a)?;
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

/// Summary of one CL run.
#[derive(Clone, Debug)]
pub struct ClReport {
    pub policy: String,
    pub matrix: AccuracyMatrix,
    /// Train-step count over the whole run (drives latency/energy).
    pub train_steps: u64,
    /// Replay-memory traffic in 128-bit bursts (reads, writes).
    pub replay_bursts: (u64, u64),
}

impl ClReport {
    pub fn final_average(&self) -> f64 {
        self.matrix.final_average()
    }
}

impl fmt::Display for ClReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "policy: {}", self.policy)?;
        write!(f, "{}", self.matrix)?;
        writeln!(
            f,
            "avg acc: {:.3}  BWT: {:+.3}  forgetting: {:.3}  steps: {}",
            self.matrix.final_average(),
            self.matrix.backward_transfer(),
            self.matrix.forgetting(),
            self.train_steps
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix(rows: &[&[f64]]) -> AccuracyMatrix {
        let mut m = AccuracyMatrix::new(rows.len());
        for r in rows {
            m.push_row(r.to_vec());
        }
        m
    }

    #[test]
    fn perfect_memory_no_forgetting() {
        let m = matrix(&[&[0.9], &[0.9, 0.8], &[0.9, 0.8, 0.85]]);
        assert!((m.final_average() - 0.85).abs() < 1e-12);
        assert_eq!(m.backward_transfer(), 0.0);
        assert_eq!(m.forgetting(), 0.0);
    }

    #[test]
    fn catastrophic_forgetting_detected() {
        let m = matrix(&[&[0.95], &[0.10, 0.95]]);
        assert!(m.backward_transfer() < -0.8);
        assert!(m.forgetting() > 0.8);
    }

    #[test]
    fn forgetting_uses_best_intermediate() {
        // Task 0 accuracy peaks after task 1, then collapses.
        let m = matrix(&[&[0.5], &[0.9, 0.9], &[0.1, 0.9, 0.9]]);
        // best over i<2 for j=0 is 0.9 → forgetting contribution 0.8.
        assert!((m.forgetting() - (0.8 + 0.0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn single_task_degenerate() {
        let m = matrix(&[&[0.7]]);
        assert_eq!(m.backward_transfer(), 0.0);
        assert_eq!(m.forgetting(), 0.0);
        assert!((m.final_average() - 0.7).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "row must cover")]
    fn wrong_row_length_rejected() {
        let mut m = AccuracyMatrix::new(3);
        m.push_row(vec![0.5, 0.5]);
    }

    #[test]
    fn display_renders_triangle() {
        let m = matrix(&[&[0.9], &[0.8, 0.7]]);
        let s = format!("{m}");
        assert!(s.contains("T0"));
        assert!(s.contains("0.700"));
    }
}
