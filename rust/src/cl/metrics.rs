//! Standard continual-learning metrics over the task-accuracy matrix.
//!
//! `R[i][j]` = accuracy on task `j`'s test set after finishing training
//! task `i`. From it: average final accuracy, backward transfer (BWT,
//! Lopez-Paz & Ranzato [18]) and the forgetting measure (Chaudhry et
//! al. [19]) — the quantities CF-avoidance policies are judged on.

use std::fmt;

/// Lower-triangular accuracy matrix filled task by task.
#[derive(Clone, Debug)]
pub struct AccuracyMatrix {
    /// `r[i][j]` for `j <= i`.
    r: Vec<Vec<f64>>,
    num_tasks: usize,
}

impl AccuracyMatrix {
    pub fn new(num_tasks: usize) -> AccuracyMatrix {
        AccuracyMatrix { r: Vec::with_capacity(num_tasks), num_tasks }
    }

    /// Record the accuracy row after finishing task `i`: one entry per
    /// task `0..=i`.
    pub fn push_row(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.r.len() + 1, "row must cover tasks 0..=i");
        assert!(self.r.len() < self.num_tasks, "matrix already complete");
        assert!(row.iter().all(|a| (0.0..=1.0).contains(a)));
        self.r.push(row);
    }

    pub fn rows_filled(&self) -> usize {
        self.r.len()
    }

    pub fn at(&self, after_task: usize, on_task: usize) -> f64 {
        self.r[after_task][on_task]
    }

    /// Average accuracy over all seen tasks after the last trained task.
    pub fn final_average(&self) -> f64 {
        let last = self.r.last().expect("empty matrix");
        last.iter().sum::<f64>() / last.len() as f64
    }

    /// Backward transfer: mean over tasks j < T of `R[T][j] − R[j][j]`.
    /// Negative BWT = forgetting.
    pub fn backward_transfer(&self) -> f64 {
        let t = self.r.len();
        if t < 2 {
            return 0.0;
        }
        let last = &self.r[t - 1];
        let sum: f64 = (0..t - 1).map(|j| last[j] - self.r[j][j]).sum();
        sum / (t - 1) as f64
    }

    /// Forgetting measure: mean over tasks j < T of
    /// `max_{i<T} R[i][j] − R[T][j]` (always ≥ 0 up to noise).
    pub fn forgetting(&self) -> f64 {
        let t = self.r.len();
        if t < 2 {
            return 0.0;
        }
        let sum: f64 = self.forgetting_per_task().iter().take(t - 1).sum();
        sum / (t - 1) as f64
    }

    /// Final accuracy per task: the last row, one entry per task — what
    /// the deployed model scores on each task after the whole schedule.
    pub fn accuracy_per_task(&self) -> Vec<f64> {
        self.r.last().expect("empty matrix").clone()
    }

    /// Per-task forgetting: for task j < T−1,
    /// `max_{j ≤ i < T−1} R[i][j] − R[T−1][j]` (how far the final
    /// accuracy fell from the best it ever was before the last task);
    /// the last task contributes 0 by convention (nothing trained after
    /// it). [`AccuracyMatrix::forgetting`] is the mean of the first
    /// T−1 entries.
    pub fn forgetting_per_task(&self) -> Vec<f64> {
        let t = self.r.len();
        let last = self.r.last().expect("empty matrix");
        (0..t)
            .map(|j| {
                if j + 1 >= t {
                    return 0.0;
                }
                let best = (j..t - 1).map(|i| self.r[i][j]).fold(f64::MIN, f64::max);
                best - last[j]
            })
            .collect()
    }

    /// Per-task backward transfer: `R[T−1][j] − R[j][j]` for j < T−1
    /// (how training later tasks moved task j relative to right after
    /// its own training); the last task contributes 0.
    /// [`AccuracyMatrix::backward_transfer`] is the mean of the first
    /// T−1 entries.
    pub fn backward_transfer_per_task(&self) -> Vec<f64> {
        let t = self.r.len();
        let last = self.r.last().expect("empty matrix");
        (0..t).map(|j| if j + 1 < t { last[j] - self.r[j][j] } else { 0.0 }).collect()
    }

    /// Per-task retention: final accuracy over the best accuracy the
    /// task ever had (`R[T−1][j] / max_{j ≤ i ≤ T−1} R[i][j]`), 1.0
    /// when the best is 0 (nothing learned ⇒ nothing forgotten). A
    /// perfectly isolated multi-head model retains exactly 1.0 on every
    /// task it stops training.
    pub fn retention_per_task(&self) -> Vec<f64> {
        let t = self.r.len();
        let last = self.r.last().expect("empty matrix");
        (0..t)
            .map(|j| {
                let best = (j..t).map(|i| self.r[i][j]).fold(f64::MIN, f64::max);
                if best == 0.0 {
                    1.0
                } else {
                    last[j] / best
                }
            })
            .collect()
    }
}

impl fmt::Display for AccuracyMatrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:>8}", "after\\on")?;
        for j in 0..self.r.len() {
            write!(f, " {:>6}", format!("T{j}"))?;
        }
        writeln!(f)?;
        for (i, row) in self.r.iter().enumerate() {
            write!(f, "{:>8}", format!("T{i}"))?;
            for a in row {
                write!(f, " {:>6.3}", a)?;
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

/// Summary of one CL run.
#[derive(Clone, Debug)]
pub struct ClReport {
    pub policy: String,
    pub matrix: AccuracyMatrix,
    /// Train-step count over the whole run (drives latency/energy).
    pub train_steps: u64,
    /// Replay-memory traffic in 128-bit bursts (reads, writes).
    pub replay_bursts: (u64, u64),
}

impl ClReport {
    pub fn final_average(&self) -> f64 {
        self.matrix.final_average()
    }
}

impl fmt::Display for ClReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "policy: {}", self.policy)?;
        write!(f, "{}", self.matrix)?;
        writeln!(
            f,
            "avg acc: {:.3}  BWT: {:+.3}  forgetting: {:.3}  steps: {}",
            self.matrix.final_average(),
            self.matrix.backward_transfer(),
            self.matrix.forgetting(),
            self.train_steps
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix(rows: &[&[f64]]) -> AccuracyMatrix {
        let mut m = AccuracyMatrix::new(rows.len());
        for r in rows {
            m.push_row(r.to_vec());
        }
        m
    }

    #[test]
    fn perfect_memory_no_forgetting() {
        let m = matrix(&[&[0.9], &[0.9, 0.8], &[0.9, 0.8, 0.85]]);
        assert!((m.final_average() - 0.85).abs() < 1e-12);
        assert_eq!(m.backward_transfer(), 0.0);
        assert_eq!(m.forgetting(), 0.0);
    }

    #[test]
    fn catastrophic_forgetting_detected() {
        let m = matrix(&[&[0.95], &[0.10, 0.95]]);
        assert!(m.backward_transfer() < -0.8);
        assert!(m.forgetting() > 0.8);
    }

    #[test]
    fn forgetting_uses_best_intermediate() {
        // Task 0 accuracy peaks after task 1, then collapses.
        let m = matrix(&[&[0.5], &[0.9, 0.9], &[0.1, 0.9, 0.9]]);
        // best over i<2 for j=0 is 0.9 → forgetting contribution 0.8.
        assert!((m.forgetting() - (0.8 + 0.0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn single_task_degenerate() {
        let m = matrix(&[&[0.7]]);
        assert_eq!(m.backward_transfer(), 0.0);
        assert_eq!(m.forgetting(), 0.0);
        assert!((m.final_average() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn per_task_vectors_match_aggregates() {
        let m = matrix(&[&[0.5], &[0.9, 0.9], &[0.1, 0.9, 0.9]]);
        let acc = m.accuracy_per_task();
        assert_eq!(acc, vec![0.1, 0.9, 0.9]);
        let f = m.forgetting_per_task();
        // j=0: best over rows 0..2 is 0.9, last 0.1 → 0.8; j=1: 0.0;
        // j=2 (last task): 0 by convention.
        assert_eq!(f, vec![0.8, 0.0, 0.0]);
        assert!((m.forgetting() - (0.8 + 0.0) / 2.0).abs() < 1e-12);
        let b = m.backward_transfer_per_task();
        assert!((b[0] - (0.1 - 0.5)).abs() < 1e-12);
        assert_eq!(b[1], 0.0);
        assert_eq!(b[2], 0.0);
        assert!((m.backward_transfer() - (b[0] + b[1]) / 2.0).abs() < 1e-12);
        let r = m.retention_per_task();
        assert!((r[0] - 0.1 / 0.9).abs() < 1e-12);
        assert_eq!(r[1], 1.0);
        assert_eq!(r[2], 1.0);
    }

    #[test]
    fn per_task_degenerate_single_task() {
        let m = matrix(&[&[0.7]]);
        assert_eq!(m.accuracy_per_task(), vec![0.7]);
        assert_eq!(m.forgetting_per_task(), vec![0.0]);
        assert_eq!(m.backward_transfer_per_task(), vec![0.0]);
        assert_eq!(m.retention_per_task(), vec![1.0]);
    }

    #[test]
    fn per_task_all_zero_retention_is_one() {
        // A task that never learned anything has nothing to forget:
        // retention 1.0, not 0/0.
        let m = matrix(&[&[0.0], &[0.0, 0.0]]);
        assert_eq!(m.retention_per_task(), vec![1.0, 1.0]);
        assert_eq!(m.forgetting_per_task(), vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "row must cover")]
    fn wrong_row_length_rejected() {
        let mut m = AccuracyMatrix::new(3);
        m.push_row(vec![0.5, 0.5]);
    }

    #[test]
    fn display_renders_triangle() {
        let m = matrix(&[&[0.9], &[0.8, 0.7]]);
        let s = format!("{m}");
        assert!(s.contains("T0"));
        assert!(s.contains("0.700"));
    }
}
