//! `tinycl` — leader binary for the TinyCL reproduction.
//!
//! Subcommands (see `tinycl help`):
//! * `train`     — run a CL experiment on a chosen backend/policy (§IV-A)
//! * `infer`     — single-sample inference on a chosen backend
//! * `sim-layer` — per-op cycle counts at the paper's geometry (§IV-B)
//! * `report-hw` — area/power/clock report + Fig. 7 breakdown + Table I
//! * `speedup`   — epoch time: TinyCL-sim vs AOT-XLA software baseline
//!                 vs the paper's P100 constant (§IV-C)
//! * `serve-bench` — replica-pool inference serving under closed-loop
//!                 and open-loop load (dynamic batching, priority lanes,
//!                 admission control, coordinated-omission-corrected
//!                 latency; emits BENCH_serve.json)
//! * `replay-bench` — latent-replay frontier: cut × byte-budget sweep of
//!                 accuracy and train time vs gdumb/er at equal byte
//!                 budgets (emits BENCH_replay.json)
//! * `obs-report` — run a small end-to-end workload and render the
//!                 process-wide metric registry (Prometheus text or
//!                 JSON snapshot)
//! * `sweep`     — design-space sweep over lanes × taps (ablation A2)

use anyhow::{bail, Result};
use tinycl::coordinator::{Backend, BackendKind, Experiment, ExperimentConfig};
use tinycl::data::SyntheticCifar;
use tinycl::hw::{comparison, CostModel, EnergyModel};
use tinycl::sim::{OpKind, SimConfig};
use tinycl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "train" => cmd_train(args),
        "infer" => cmd_infer(args),
        "sim-layer" => cmd_sim_layer(args),
        "report-hw" => cmd_report_hw(args),
        "speedup" => cmd_speedup(args),
        "serve-bench" => tinycl::serve::bench::run(args),
        "replay-bench" => tinycl::cl::bench::run(args),
        "obs-report" => cmd_obs_report(args),
        "sweep" => cmd_sweep(args),
        "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' — try `tinycl help`"),
    }
}

const HELP: &str = "\
tinycl — TinyCL (Ressa et al., 2024) reproduction

USAGE: tinycl <SUBCOMMAND> [flags]

SUBCOMMANDS
  train      run a continual-learning experiment
             --backend f32|f32-fast|qnn|sim|xla
             --policy gdumb|er|naive|joint|latent-replay
             (the `xla` backend needs a build with `--features xla`)
             --tasks N --epochs N --lr F --memory N --per-class N
             --memory-bytes N (replay budget in bytes instead of slots;
             the paper's memory is 6144000)
             --replay-cut 0|1|2 (latent-replay only: freeze the prefix
             and store activations at the cut; 0 = raw inputs = gdumb,
             1 = post-conv1, 2 = post-conv2, dense-only training)
             --batch N (minibatch size; float backends run one batched
             GEMM set per minibatch, others loop per sample)
             --threads N (GEMM worker threads, 0 = auto; results are
             bit-identical at any thread count)
             --qnn-engine naive|fast (Q4.12 compute engine; fast is the
             integer im2col+GEMM path, bit-identical to the naive oracle)
             --image-size N --conv-channels N --classes N --seed N
  infer      one inference on a trained-from-scratch model
             --backend ... --image-size ... (same model flags)
  sim-layer  per-operation cycle counts at the paper geometry (§IV-B)
             --image-size N --conv-channels N --classes N
  report-hw  synthesized-design report: clock, area, power (Fig. 7),
             Table I comparison  [--lanes N --taps N]
  speedup    1 training epoch: TinyCL cycles vs XLA baseline wall time
             --steps N (default: one GDumb epoch of 1000)
             --batch N --threads N (batched+threaded f32-fast rung)
             (also times the qnn naive vs fast integer-GEMM rung)
  serve-bench  multi-client inference serving: replica pool + dynamic
             batcher + priority lanes + admission control. Rungs:
             max_batch 1 vs N ladder, replicas 1 vs N ladder, an
             open-loop saturation sweep (timed arrivals, coordinated-
             omission-corrected latency, achieved-vs-offered knee),
             and an SLO-attainment rung at 0.9× the knee with
             serve-while-learning on, per-request deadlines, a
             watchdog, the autoscaler healing an injected replica
             kill mid-run, and diff-only weight re-broadcast, plus a
             multitask rung: K per-task dense heads on one shared
             frozen backbone behind the task router, head-only train
             bursts through the serve path, bit-exact head-isolation /
             zero-growth-byte / equal-load-throughput gates
             --backend f32|f32-fast|qnn|sim (default: both fast backends)
             --tasks K (multitask head count, default 3; ≤ 1 skips)
             --task-schedule roundrobin|blocked|random (load interleave)
             --clients N (default 8) --requests N (default 2000)
             --max-batch N (default 64) --max-wait-us N (default 200)
             --queue-depth N (shed beyond it per lane; default
             2×clients, min 8)
             --replicas N (replica-ladder top, default 2; 1 skips)
             --open-loop=false (skip the sweep) --arrival-rate R (req/s,
             single point) --arrival-process poisson|uniform
             --slo=false (skip the fault-injected SLO rung)
             --threads N --qnn-engine naive|fast --seed N
             --smoke (tiny geometry, CI-safe; ratio asserts relaxed)
             asserts batching ≥ 2× and 2-replica f32-fast ≥ 1.5× at the
             paper geometry, and parity with per-sample predict on every
             rung; writes BENCH_serve.json
  replay-bench  latent-replay memory–latency–accuracy frontier: sweeps
             replay cut × byte budget and runs gdumb/er at the same
             byte budgets for comparison
             --backend f32-fast|f32|qnn (default f32-fast)
             --budgets-kb LIST (byte budgets in kB, default
             6144,3072,1536 — the paper's memory and halvings)
             --tasks N --epochs N --batch N --per-class N
             --threads N --qnn-engine naive|fast --seed N
             --smoke (tiny geometry, CI-safe; ratio asserts relaxed)
             asserts an interior cut trains ≥ 2× faster than gdumb at
             the paper geometry's largest budget; writes
             BENCH_replay.json
  obs-report exercise a small end-to-end workload (a few train steps,
             then a short served burst) and print the process-wide
             metric registry
             --format prom|json (default prom: Prometheus text
             exposition; json: the same snapshot as --metrics-json)
             --steps N (train steps, default 8)
             --requests N (served predicts, default 32)
             --backend ... (default f32-fast; same model flags as `infer`)
  sweep      design-space sweep over --lanes-list and --taps-list
  help       this text
";

/// `train`: the paper's §IV-A experiment, configurable.
fn cmd_train(args: &Args) -> Result<()> {
    let config = ExperimentConfig::from_args(args)?;
    eprintln!(
        "running CL experiment: backend={} policy={} …",
        config.backend.name(),
        config.policy.name()
    );
    let result = Experiment::new(config).run()?;
    println!("{result}");
    Ok(())
}

/// `infer`: single forward pass, print logits (smoke / demo).
fn cmd_infer(args: &Args) -> Result<()> {
    let config = ExperimentConfig::from_args(args)?;
    let mut backend = Experiment::new(config.clone()).backend()?;
    let gen = SyntheticCifar {
        image_size: config.model.image_size,
        channels: config.model.in_channels,
        num_classes: config.model.num_classes,
        noise: config.noise,
        seed: config.seed,
    };
    let data = gen.generate(1, 2);
    for s in data.samples.iter().take(args.usize_or("count", 3)) {
        use tinycl::cl::Learner;
        let pred = backend.predict(&s.x, config.model.num_classes);
        println!(
            "label={} pred={} {}",
            s.label,
            pred,
            if pred == s.label { "✓" } else { "✗" }
        );
    }
    Ok(())
}

/// `sim-layer`: E1 — per-op cycles of one train step.
fn cmd_sim_layer(args: &Args) -> Result<()> {
    let config = ExperimentConfig::from_args(args)?;
    let mut backend = Backend::create(
        BackendKind::Sim,
        &config.model,
        &config.sim,
        &config.artifacts_dir,
        config.seed,
    )?;
    use tinycl::cl::Learner;
    let gen = SyntheticCifar {
        image_size: config.model.image_size,
        channels: config.model.in_channels,
        num_classes: config.model.num_classes,
        noise: config.noise,
        seed: config.seed,
    };
    let s = &gen.generate(1, 0).samples[0];
    backend.train_step(&s.x, s.label, config.model.num_classes, config.lr);
    let (train, _) = backend.sim_stats().unwrap();
    println!("one train step at {}×{}×{} in, {} filters:",
        config.model.image_size, config.model.image_size, config.model.in_channels,
        config.model.conv_channels);
    println!("{train}");
    println!("paper §IV-B reference (32×32×8 in, 8 filters): conv fwd / grad-prop / kgrad = 8192 each; dense fwd 1280, dense dX 1821, dense dW 1280");
    Ok(())
}

/// `report-hw`: E2 + E3 — Fig. 7 breakdown and Table I.
fn cmd_report_hw(args: &Args) -> Result<()> {
    let config = ExperimentConfig::from_args(args)?;
    let cost = CostModel::for_design(&config.sim, &config.model);

    // Measure one train step's activity for the power column.
    let mut backend = Backend::create(
        BackendKind::Sim,
        &config.model,
        &config.sim,
        &config.artifacts_dir,
        config.seed,
    )?;
    use tinycl::cl::Learner;
    let gen = SyntheticCifar::default();
    let s = &gen.generate(1, 0).samples[0];
    backend.train_step(&s.x, s.label, config.model.num_classes, config.lr);
    let (train, _) = backend.sim_stats().unwrap();

    println!("=== design report ({} taps × {} lanes) ===", config.sim.taps, config.sim.lanes);
    println!("{}", cost.report(train));
    println!("paper §IV-B: 3.87 ns, 86 mW, 4.74 mm²; Fig. 7: memory ≈80% area / ≈76% power\n");

    println!("=== Table I ===");
    print!("{}", comparison::render_table1(&comparison::table1_rows(&cost, train)));

    let energy = EnergyModel::new(cost);
    println!("\n=== energy of one train step ===");
    print!("{}", energy.report(train, 0));
    Ok(())
}

/// `speedup`: E4 — one training epoch on sim (cycles → seconds at the
/// synthesized clock) vs this host's software baselines: the naive f32
/// reference, the im2col+GEMM `f32-fast` core and — when built with
/// `--features xla` — the AOT-XLA executable. The paper's P100 constant
/// is carried alongside for reference.
fn cmd_speedup(args: &Args) -> Result<()> {
    let config = ExperimentConfig::from_args(args)?;
    let steps = args.usize_or("steps", 1000);
    let gen = SyntheticCifar::default();
    let per_class = steps.div_ceil(10).max(1);
    let data = gen.generate(per_class, 0);
    let samples: Vec<_> = data.samples.iter().take(steps).collect();

    use tinycl::cl::Learner;

    let run_host = |kind: BackendKind| -> Result<f64> {
        let mut backend = Backend::create(
            kind, &config.model, &config.sim, &config.artifacts_dir, config.seed)?;
        let t0 = std::time::Instant::now();
        for s in &samples {
            backend.train_step(&s.x, s.label, config.model.num_classes, config.lr);
        }
        Ok(t0.elapsed().as_secs_f64())
    };

    // Host software baselines.
    let naive_secs = run_host(BackendKind::F32)?;
    let fast_secs = run_host(BackendKind::F32Fast)?;

    // Q4.12 oracle rung: naive loops vs the bit-identical integer GEMM.
    let run_qnn = |engine: tinycl::qnn::QnnEngine, threads: usize| -> Result<f64> {
        let mut backend = Backend::create(
            BackendKind::Qnn, &config.model, &config.sim, &config.artifacts_dir, config.seed)?;
        backend.set_qnn_engine(engine);
        backend.set_threads(threads);
        let t0 = std::time::Instant::now();
        for s in &samples {
            backend.train_step(&s.x, s.label, config.model.num_classes, config.lr);
        }
        Ok(t0.elapsed().as_secs_f64())
    };
    let qnn_naive_secs = run_qnn(tinycl::qnn::QnnEngine::Naive, 1)?;
    let qnn_fast_secs = run_qnn(tinycl::qnn::QnnEngine::Fast, config.threads)?;

    // Batched + threaded f32-fast rung (PR 2's training engine). The
    // thread budget comes from the shared config parse (--threads 0 =
    // auto); only the batch default differs from `train` (8 makes the
    // rung meaningful without flags).
    let batch = args.usize_or("batch", 8).max(1);
    let threads = config.threads;
    let batched_secs = {
        let kind = BackendKind::F32Fast;
        let mut backend =
            Backend::create(kind, &config.model, &config.sim, &config.artifacts_dir, config.seed)?;
        backend.set_threads(threads);
        let t0 = std::time::Instant::now();
        for chunk in samples.chunks(batch) {
            let xs: Vec<&tinycl::tensor::Tensor<f32>> = chunk.iter().map(|s| &s.x).collect();
            let labels: Vec<usize> = chunk.iter().map(|s| s.label).collect();
            backend.train_batch(&xs, &labels, config.model.num_classes, config.lr);
        }
        t0.elapsed().as_secs_f64()
    };

    #[cfg(feature = "xla")]
    let xla_secs = Some(run_host(BackendKind::Xla)?);
    #[cfg(not(feature = "xla"))]
    let xla_secs: Option<f64> = None;

    // TinyCL device.
    let mut sim = Backend::create(
        BackendKind::Sim, &config.model, &config.sim, &config.artifacts_dir, config.seed)?;
    for s in &samples {
        sim.train_step(&s.x, s.label, config.model.num_classes, config.lr);
    }
    let (train, _) = sim.sim_stats().unwrap();
    let cost = CostModel::for_design(&config.sim, &config.model);
    let sim_secs = train.cycles() as f64 * cost.clock_ns() * 1e-9;

    // The paper's constants for the same nominal workload.
    let paper_gpu = 103.0;
    let paper_tinycl = 1.76;

    println!("one epoch = {steps} train steps (batch 1)");
    println!("TinyCL (sim, {:.2} ns clock): {:.3} s  ({} cycles)",
        cost.clock_ns(), sim_secs, train.cycles());
    println!("f32 naive baseline (this host): {naive_secs:.3} s");
    println!("f32-fast GEMM baseline (this host): {fast_secs:.3} s  ({:.1}× over naive)",
        naive_secs / fast_secs);
    println!(
        "f32-fast batched (batch {batch}, {threads} threads): {batched_secs:.3} s  \
         ({:.1}× over batch-1 f32-fast)",
        fast_secs / batched_secs
    );
    println!("qnn naive Q4.12 oracle (this host): {qnn_naive_secs:.3} s");
    println!(
        "qnn fast integer-GEMM oracle (this host): {qnn_fast_secs:.3} s  \
         ({:.1}× over naive qnn, bit-identical)",
        qnn_naive_secs / qnn_fast_secs
    );
    match xla_secs {
        Some(x) => println!("XLA CPU baseline (this host): {x:.3} s"),
        None => println!("XLA CPU baseline: skipped (built without the `xla` feature)"),
    }
    println!("speedup vs this host's fastest software baseline: {:.1}×",
        xla_secs.unwrap_or(f64::INFINITY).min(fast_secs).min(batched_secs) / sim_secs);
    println!("paper: TinyCL {paper_tinycl} s vs P100 {paper_gpu} s ⇒ 58× (their testbed)");
    Ok(())
}

/// `obs-report`: run a small representative workload — a few train
/// steps to light up the engine counters, then a short served burst so
/// the span histograms and flush books have entries — and render the
/// process-wide metric registry. The CI smoke uses this as the
/// exporter's end-to-end check; `--format json` prints the same
/// snapshot document `--metrics-json` writes on the benches.
fn cmd_obs_report(args: &Args) -> Result<()> {
    let mut config = ExperimentConfig::from_args(args)?;
    if args.get("backend").is_none() {
        // The GEMM engine counters are the report's most interesting
        // rows — default to the im2col+GEMM core, not the naive loops.
        config.backend = BackendKind::F32Fast;
    }
    let mut backend = Experiment::new(config.clone()).backend()?;
    let gen = SyntheticCifar {
        image_size: config.model.image_size,
        channels: config.model.in_channels,
        num_classes: config.model.num_classes,
        noise: config.noise,
        seed: config.seed,
    };
    let data = gen.generate(8, 0);

    use tinycl::cl::Learner;
    for s in data.samples.iter().take(args.usize_or("steps", 8)) {
        backend.train_step(&s.x, s.label, config.model.num_classes, config.lr);
    }

    let server =
        tinycl::serve::Server::start(backend, tinycl::serve::ServerConfig::default());
    let client = server.client();
    for s in data.samples.iter().cycle().take(args.usize_or("requests", 32)) {
        let _ = client.predict(&s.x, config.model.num_classes);
    }
    let _ = server.shutdown();

    match args.str_or("format", "prom").as_str() {
        "prom" => print!("{}", tinycl::obs::export::prometheus()),
        "json" => print!("{}", tinycl::obs::export::json_snapshot()),
        other => bail!("unknown --format '{other}' (expected prom|json)"),
    }
    Ok(())
}

/// `sweep`: A2 — design-space sweep (lanes × taps).
fn cmd_sweep(args: &Args) -> Result<()> {
    let config = ExperimentConfig::from_args(args)?;
    let lanes_list = args.usize_list_or("lanes-list", "2,4,8,16");
    let taps_list = args.usize_list_or("taps-list", "9");
    use tinycl::cl::Learner;

    println!(
        "{:<6} {:<6} {:>12} {:>10} {:>10} {:>10} {:>12}",
        "taps", "lanes", "cycles/step", "clock ns", "area mm²", "power mW", "µJ/step"
    );
    for &taps in &taps_list {
        for &lanes in &lanes_list {
            let sim_cfg = SimConfig::paper().with_lanes(lanes).with_taps(taps);
            let mut backend = Backend::create(
                BackendKind::Sim, &config.model, &sim_cfg, &config.artifacts_dir, config.seed)?;
            let gen = SyntheticCifar {
                image_size: config.model.image_size,
                channels: config.model.in_channels,
                num_classes: config.model.num_classes,
                noise: config.noise,
                seed: config.seed,
            };
            let s = &gen.generate(1, 0).samples[0];
            backend.train_step(&s.x, s.label, config.model.num_classes, config.lr);
            let (train, _) = backend.sim_stats().unwrap();
            let cost = CostModel::for_design(&sim_cfg, &config.model);
            let energy = EnergyModel::new(CostModel::for_design(&sim_cfg, &config.model));
            println!(
                "{:<6} {:<6} {:>12} {:>10.2} {:>10.2} {:>10.1} {:>12.2}",
                taps,
                lanes,
                train.cycles(),
                cost.clock_ns(),
                cost.area_mm2().total(),
                cost.power_mw(train).total(),
                energy.report(train, 0).total_uj(),
            );
        }
    }
    let _ = OpKind::ALL; // keep OpKind linked for future per-op sweeps
    Ok(())
}
