//! Unified observability: metric registry, serve-path lifecycle spans,
//! and the fault flight recorder.
//!
//! Until this PR, telemetry was fragmented — `serve/metrics.rs` kept
//! its own latency vectors, `sim/stats.rs` its own cycle table, each
//! bench its own JSON writer — and a replica that died under PR 8's
//! fault injection left no trace of what it was doing. This module is
//! the one place signals flow through:
//!
//! * [`registry`] — process-wide named **counters**, **gauges** and
//!   log2 latency **histograms** ([`hist`]), all backed by sharded
//!   atomics (one cache-padded cell per worker, merged at read) so a
//!   hot-path increment is one relaxed `fetch_add` with no contention.
//! * [`span`] — per-request lifecycle stamps on the serve `Clock` seam
//!   (admission → queue-wait → assembly → compute → respond), recorded
//!   into per-lane/per-replica stage histograms.
//! * [`recorder`] — a bounded lock-free per-replica event ring (flush
//!   decisions, barrier transitions, faults, steals, resyncs), dumped
//!   automatically on organic panic, watchdog steal and shutdown.
//! * [`export`] — Prometheus text + JSON snapshot emitters feeding
//!   `tinycl obs-report`, `--metrics-json`, and the metrics block
//!   embedded in `BENCH_serve.json`.
//!
//! **Overhead contract**: instrumentation stays on by default and must
//! cost ≤ 3% serve-path p99 (asserted by the serve bench's obs rung).
//! Two kill-switches honor it: the `obs-off` cargo feature compiles
//! [`enabled`] to a constant `false` (every hook folds away), and
//! [`set_enabled`]`(false)` is the runtime equivalent — one relaxed
//! load on the hot path. Dependency-free, like the rest of the crate.

pub mod export;
pub mod hist;
pub mod recorder;
pub mod registry;
pub mod span;

pub use hist::{HistSnapshot, Histogram};
pub use recorder::{Event, FlightRecorder, FlushWhy, Ring};
pub use registry::{count_gemm, counter, gauge, histogram, record_us, Counter, Gauge};
pub use span::{SpanStamps, Stage, STAGES};

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Is instrumentation live? With the `obs-off` feature this is a
/// constant `false` and every gated hook compiles out; otherwise it is
/// one relaxed atomic load (the runtime kill-switch).
#[inline(always)]
pub fn enabled() -> bool {
    if cfg!(feature = "obs-off") {
        return false;
    }
    ENABLED.load(Ordering::Relaxed)
}

/// Runtime kill-switch (the obs-overhead bench rung measures with this
/// off as its baseline). No-op under `obs-off` (already off for good).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// This thread's metric shard: assigned round-robin at first use, so
/// pool workers and replica threads each get their own cache line in
/// sharded counters/histograms. Masked by the shard count at use.
#[inline]
pub fn shard_index() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: usize = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    SHARD.with(|s| *s)
}

/// Serializes unit tests that read global counters or toggle the
/// kill-switch — the registry and `ENABLED` are process-wide, so
/// count-asserting tests must not interleave with the toggle test.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    use std::sync::{Mutex, OnceLock};
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_index_is_stable_per_thread() {
        let a = shard_index();
        assert_eq!(a, shard_index());
        let b = std::thread::spawn(shard_index).join().unwrap();
        // A fresh thread gets the next round-robin slot, never racing
        // onto this thread's cell.
        assert_ne!(a, b);
    }

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn runtime_kill_switch_gates_recording() {
        let _guard = test_lock();
        let c = registry::counter("test_obs_kill_switch_total");
        c.add(1);
        set_enabled(false);
        c.add(10);
        set_enabled(true);
        c.add(2);
        assert_eq!(c.get(), 3);
    }
}
