//! Per-request lifecycle spans for the serve path.
//!
//! Every request is stamped on the serve `Clock` seam (so `MockClock`
//! makes span tests deterministic and sleep-free) at five points:
//!
//! ```text
//! admission ──queue_wait──▶ joined batch ──assembly──▶ compute start
//!           ──compute──▶ compute end ──respond──▶ response sent
//! ```
//!
//! * **queue_wait** — admitted into the lane queue until popped into an
//!   open batch (lane aging, fences and pauses all show up here).
//! * **assembly** — sitting in the open batch while `flush_decision`
//!   waits for more work or a deadline.
//! * **compute** — the batched forward pass (plus flight check-in).
//! * **respond** — compute done until the outcome hits the channel.
//!
//! The four stages partition the server-side end-to-end latency by
//! construction: `sum(stages) == done - admitted` exactly (saturating
//! only if a clock ever stepped backwards, which `MockClock` and the
//! monotonic `WallClock` rule out). That identity is the acceptance
//! gate `sum(stage means) == end-to-end mean` — exact on the lossless
//! histogram sums, not approximate.

/// The four serve-path stages, in lifecycle order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    QueueWait,
    Assembly,
    Compute,
    Respond,
}

/// All stages in order (iteration + metric registration).
pub const STAGES: [Stage; 4] = [Stage::QueueWait, Stage::Assembly, Stage::Compute, Stage::Respond];

impl Stage {
    /// Label value used in metric names
    /// (`serve_stage_us{stage="queue_wait",lane="interactive"}`).
    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::Assembly => "assembly",
            Stage::Compute => "compute",
            Stage::Respond => "respond",
        }
    }
}

/// The five clock stamps of one request's life, µs on the server's
/// `Clock`. Built incrementally: admission stamps `admitted_us`, the
/// batch pop stamps `assembled_us`, the replica stamps the compute
/// bracket, and the respond site closes the span.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanStamps {
    pub admitted_us: u64,
    pub assembled_us: u64,
    pub compute_start_us: u64,
    pub compute_end_us: u64,
    pub done_us: u64,
}

impl SpanStamps {
    /// Stage durations in lifecycle order, saturating per stage.
    pub fn stage_us(&self) -> [u64; 4] {
        [
            self.assembled_us.saturating_sub(self.admitted_us),
            self.compute_start_us.saturating_sub(self.assembled_us),
            self.compute_end_us.saturating_sub(self.compute_start_us),
            self.done_us.saturating_sub(self.compute_end_us),
        ]
    }

    /// Server-side end-to-end: admission to response.
    pub fn e2e_us(&self) -> u64 {
        self.done_us.saturating_sub(self.admitted_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_partition_end_to_end() {
        let s = SpanStamps {
            admitted_us: 100,
            assembled_us: 130,
            compute_start_us: 150,
            compute_end_us: 950,
            done_us: 960,
        };
        assert_eq!(s.stage_us(), [30, 20, 800, 10]);
        assert_eq!(s.stage_us().iter().sum::<u64>(), s.e2e_us());
    }

    #[test]
    fn stage_names_are_stable() {
        let names: Vec<&str> = STAGES.iter().map(|s| s.name()).collect();
        assert_eq!(names, ["queue_wait", "assembly", "compute", "respond"]);
    }
}
