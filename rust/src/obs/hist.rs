//! Fixed-bucket log2 latency histogram backed by sharded atomics.
//!
//! The serve hot path stamps five timestamps per request and records
//! four stage durations; a mutex-guarded `Vec<f64>` there would put a
//! contended lock on every response. Instead each histogram keeps
//! [`SHARDS`] independent cache-line-padded cells per bucket; a thread
//! picks its shard once (round-robin thread-local) and every record is
//! a handful of relaxed `fetch_add`s on lines no other core is writing.
//! Reads merge all shards — reads are rare (export time), writes are
//! the hot path.
//!
//! Bucketing: values are microseconds; bucket 0 holds exactly 0, bucket
//! `i ≥ 1` holds `[2^(i-1), 2^i)`, and the last bucket is an overflow
//! catch-all. Log2 buckets give a bounded **relative** quantile error:
//! a reported quantile and the true value land in the same bucket, so
//! `est/true ∈ (0.5, 2]` — pinned by the Python differential
//! (`python/tests/test_histogram.py`) and the unit tests below, which
//! share fixed constants.
//!
//! The `sum`/`count`/`max` side-channels are exact (not bucket-derived),
//! so **means are lossless**: the serve-span acceptance check
//! `sum(stage means) == end-to-end mean` holds to the microsecond, not
//! to bucket resolution. [`HistSnapshot::merge`] is lossless with
//! respect to the representation: bucket-wise addition commutes with
//! recording, so merging per-replica snapshots equals one histogram fed
//! the union stream — the fix for `LatencySummary`'s old
//! re-sort-the-raw-vectors merge.

use std::sync::atomic::{AtomicU64, Ordering};

use super::shard_index;

/// Bucket count: bucket 0 = zero, buckets 1..=38 cover `[1, 2^38) µs`
/// (2^38 µs ≈ 76 h), bucket 39 is the overflow catch-all.
pub const NBUCKETS: usize = 40;

/// Shards per histogram. Power of two so shard selection is a mask.
pub const SHARDS: usize = 16;

/// Bucket index for a value in µs (shared constant of the Python
/// differential: `bucket_index(v) = 0` if `v == 0` else
/// `min(floor(log2(v)) + 1, NBUCKETS - 1)`).
#[inline]
pub fn bucket_index(us: u64) -> usize {
    if us == 0 {
        0
    } else {
        ((64 - us.leading_zeros()) as usize).min(NBUCKETS - 1)
    }
}

/// Inclusive lower bound of a bucket, in µs.
#[inline]
pub fn bucket_lo(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

/// Exclusive upper bound of a bucket, in µs (the overflow bucket
/// reports its lower bound doubled, the best it can say).
#[inline]
pub fn bucket_hi(i: usize) -> u64 {
    1u64 << i
}

#[repr(align(64))]
struct Shard {
    buckets: [AtomicU64; NBUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

/// Sharded log2 histogram of µs values. Cheap to record (`Relaxed`
/// adds on a thread-private shard), merged at read.
pub struct Histogram {
    shards: Box<[Shard]>,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { shards: (0..SHARDS).map(|_| Shard::new()).collect() }
    }

    /// Record one µs observation. Callers gate on [`crate::obs::enabled`];
    /// this method itself never checks (handle holders may batch-gate).
    #[inline]
    pub fn record_us(&self, us: u64) {
        let s = &self.shards[shard_index() & (SHARDS - 1)];
        s.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        s.count.fetch_add(1, Ordering::Relaxed);
        s.sum.fetch_add(us, Ordering::Relaxed);
        s.max.fetch_max(us, Ordering::Relaxed);
    }

    /// Merge every shard into an owned snapshot.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut out = HistSnapshot::empty();
        for s in self.shards.iter() {
            for (i, b) in s.buckets.iter().enumerate() {
                out.buckets[i] += b.load(Ordering::Relaxed);
            }
            out.count += s.count.load(Ordering::Relaxed);
            out.sum += s.sum.load(Ordering::Relaxed);
            out.max = out.max.max(s.max.load(Ordering::Relaxed));
        }
        out
    }
}

/// An owned, mergeable histogram snapshot.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; NBUCKETS],
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl HistSnapshot {
    pub fn empty() -> HistSnapshot {
        HistSnapshot { buckets: [0; NBUCKETS], count: 0, sum: 0, max: 0 }
    }

    /// Build a snapshot from raw values (tests and one-shot summaries).
    pub fn of_us(values: impl IntoIterator<Item = u64>) -> HistSnapshot {
        let mut s = HistSnapshot::empty();
        for v in values {
            s.buckets[bucket_index(v)] += 1;
            s.count += 1;
            s.sum += v;
            s.max = s.max.max(v);
        }
        s
    }

    /// Lossless merge: recording a stream into two histograms and
    /// merging equals recording the union into one (bucket-wise adds
    /// commute; sum/count/max compose exactly).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Exact mean in µs (from the lossless sum, not the buckets).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank quantile estimate in µs: find the bucket holding
    /// the rank-`ceil(q·count)` observation and interpolate linearly
    /// across it by rank position. The true quantile lies in the same
    /// bucket, so the estimate is within a factor of 2 (shared
    /// convention of the Python differential). `max` clamps the top so
    /// `quantile(1.0) == max` exactly.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for i in 0..NBUCKETS {
            let n = self.buckets[i];
            if n == 0 {
                continue;
            }
            if seen + n >= rank {
                let lo = bucket_lo(i) as f64;
                let hi = (bucket_hi(i) as f64).min(self.max.max(1) as f64);
                let frac = (rank - seen) as f64 / n as f64;
                return (lo + (hi - lo) * frac).min(self.max as f64);
            }
            seen += n;
        }
        self.max as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), NBUCKETS - 1);
        for i in 1..NBUCKETS - 1 {
            assert_eq!(bucket_index(bucket_lo(i)), i);
            assert_eq!(bucket_index(bucket_hi(i) - 1), i);
        }
    }

    #[test]
    fn merge_is_lossless_wrt_union() {
        let a: Vec<u64> = (0..500).map(|i| i * 37 % 10_000).collect();
        let b: Vec<u64> = (0..300).map(|i| i * 91 % 1_000_000).collect();
        let mut merged = HistSnapshot::of_us(a.iter().copied());
        merged.merge(&HistSnapshot::of_us(b.iter().copied()));
        let union = HistSnapshot::of_us(a.into_iter().chain(b));
        assert_eq!(merged, union);
    }

    #[test]
    fn mean_is_exact_and_quantile_within_a_factor_of_two() {
        // Constants shared with python/tests/test_histogram.py: the
        // stream i² mod 65521 for i in 0..1000, quantiles 0.5/0.95/0.99.
        let values: Vec<u64> = (0u64..1000).map(|i| (i * i) % 65_521).collect();
        let snap = HistSnapshot::of_us(values.iter().copied());
        let exact_sum: u64 = values.iter().sum();
        assert_eq!(snap.sum, exact_sum);
        assert_eq!(snap.count, 1000);
        assert!((snap.mean_us() - exact_sum as f64 / 1000.0).abs() < 1e-9);

        let mut sorted = values.clone();
        sorted.sort_unstable();
        for q in [0.5, 0.95, 0.99] {
            let rank = ((q * 1000.0).ceil() as usize).clamp(1, 1000);
            let truth = sorted[rank - 1] as f64;
            let est = snap.quantile_us(q);
            assert!(
                est / truth.max(1.0) <= 2.0 && truth / est.max(1.0) <= 2.0,
                "q={q}: est {est} vs true {truth} outside the 2x bound"
            );
            // Bucket-bounds invariant (the sharper claim the
            // differential pins): the estimate stays inside the true
            // value's bucket range.
            let bi = bucket_index(truth as u64);
            assert!(
                bucket_lo(bi) as f64 <= est && est <= bucket_hi(bi) as f64,
                "q={q}: estimate {est} left the true value's bucket {bi}"
            );
        }
        assert_eq!(snap.quantile_us(1.0), snap.max as f64);
    }

    #[test]
    fn sharded_recording_merges_to_the_full_stream() {
        let h = Histogram::new();
        std::thread::scope(|scope| {
            for t in 0..4 {
                let h = &h;
                scope.spawn(move || {
                    for i in 0..1000u64 {
                        h.record_us(t * 1000 + i);
                    }
                });
            }
        });
        let snap = h.snapshot();
        assert_eq!(snap.count, 4000);
        assert_eq!(snap.sum, (0..4000u64).sum());
        assert_eq!(snap.max, 3999);
    }
}
