//! Flight recorder: a bounded lock-free ring of recent structured
//! events per replica, dumped when something dies.
//!
//! PR 8's fault machinery can kill a replica mid-batch, steal its
//! flight from the watchdog, or take the whole pool down — and until
//! now a `serve_faults` failure printed a panic message and nothing
//! else. Each replica now records its last [`RING_CAP`] decisions
//! (flush reasons, barrier transitions, fault injections, steals,
//! resyncs) into a fixed ring; [`FlightRecorder::dump`] renders every
//! ring, newest last, and is invoked automatically on organic panic
//! (crash-guard unwind), watchdog steal, and `shutdown_all`.
//!
//! Writer side is lock-free: one `fetch_add` claims a slot, then a
//! seqlock-style sequence stamp brackets the field writes (odd =
//! in-progress). The reader (dump time, rare) retries nothing — it
//! simply skips slots whose stamp is torn. Losing one event under a
//! racing dump is acceptable for a debugging aid; blocking the serve
//! hot path is not.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::enabled;

/// Events kept per replica ring.
pub const RING_CAP: usize = 64;

/// Why a predict batch was released to compute — the reason carried by
/// every `serve::queue::flush_decision` flush (and by the orphan-replay
/// pop, which never consults the flush rule).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushWhy {
    /// Batch reached `max_batch`.
    Full,
    /// `max_wait` elapsed since the batch opened.
    MaxWait,
    /// Arrivals went idle — nothing more is coming soon.
    Idle,
    /// A queued train fence made further waiting pointless.
    Fence,
    /// The queue is closing (shutdown drain).
    Closed,
    /// An orphaned batch replayed after a replica death/steal.
    Replay,
}

impl FlushWhy {
    pub fn name(self) -> &'static str {
        match self {
            FlushWhy::Full => "full",
            FlushWhy::MaxWait => "max_wait",
            FlushWhy::Idle => "idle",
            FlushWhy::Fence => "fence",
            FlushWhy::Closed => "closed",
            FlushWhy::Replay => "replay",
        }
    }
}

/// A structured flight-recorder event. Encoded into three `u64`s in the
/// ring; the schema is part of the README's observability contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    ReplicaStart,
    ReplicaExit,
    /// An open batch flushed: why, and how many jobs it carried.
    Flush { why: FlushWhy, batch: u64 },
    /// Train barrier: replica started leading a quiesce.
    BarrierEnter,
    /// All replicas parked; orphans harvested.
    BarrierQuiesced,
    /// Barrier done, queue resumed.
    BarrierResume { spawned: u64 },
    /// Fault injector fired a panic on this replica.
    FaultPanic,
    /// Fault injector parked this replica mid-batch.
    FaultStall,
    /// Watchdog stole this replica's flight (jobs re-queued).
    Stolen { jobs: u64 },
    /// Weights re-broadcast after a barrier (diff or full).
    Resync { diff: bool, bytes: u64 },
    /// A train request was executed at a stream cut.
    Train { cut: u64 },
}

impl Event {
    fn encode(self) -> (u64, u64, u64) {
        match self {
            Event::ReplicaStart => (0, 0, 0),
            Event::ReplicaExit => (1, 0, 0),
            Event::Flush { why, batch } => (2, why as u64, batch),
            Event::BarrierEnter => (3, 0, 0),
            Event::BarrierQuiesced => (4, 0, 0),
            Event::BarrierResume { spawned } => (5, spawned, 0),
            Event::FaultPanic => (6, 0, 0),
            Event::FaultStall => (7, 0, 0),
            Event::Stolen { jobs } => (8, jobs, 0),
            Event::Resync { diff, bytes } => (9, u64::from(diff), bytes),
            Event::Train { cut } => (10, cut, 0),
        }
    }

    fn decode(kind: u64, a: u64, b: u64) -> Option<Event> {
        Some(match kind {
            0 => Event::ReplicaStart,
            1 => Event::ReplicaExit,
            2 => Event::Flush {
                why: match a {
                    0 => FlushWhy::Full,
                    1 => FlushWhy::MaxWait,
                    2 => FlushWhy::Idle,
                    3 => FlushWhy::Fence,
                    4 => FlushWhy::Closed,
                    5 => FlushWhy::Replay,
                    _ => return None,
                },
                batch: b,
            },
            3 => Event::BarrierEnter,
            4 => Event::BarrierQuiesced,
            5 => Event::BarrierResume { spawned: a },
            6 => Event::FaultPanic,
            7 => Event::FaultStall,
            8 => Event::Stolen { jobs: a },
            9 => Event::Resync { diff: a != 0, bytes: b },
            10 => Event::Train { cut: a },
            _ => return None,
        })
    }

    /// One-line rendering used by dumps (`event=flush why=full batch=8`).
    pub fn render(&self) -> String {
        match self {
            Event::ReplicaStart => "event=replica_start".to_string(),
            Event::ReplicaExit => "event=replica_exit".to_string(),
            Event::Flush { why, batch } => {
                format!("event=flush why={} batch={batch}", why.name())
            }
            Event::BarrierEnter => "event=barrier_enter".to_string(),
            Event::BarrierQuiesced => "event=barrier_quiesced".to_string(),
            Event::BarrierResume { spawned } => {
                format!("event=barrier_resume spawned={spawned}")
            }
            Event::FaultPanic => "event=fault_panic".to_string(),
            Event::FaultStall => "event=fault_stall".to_string(),
            Event::Stolen { jobs } => format!("event=stolen jobs={jobs}"),
            Event::Resync { diff, bytes } => {
                format!("event=resync kind={} bytes={bytes}", if *diff { "diff" } else { "full" })
            }
            Event::Train { cut } => format!("event=train cut={cut}"),
        }
    }
}

struct Slot {
    seq: AtomicU64,
    t_us: AtomicU64,
    kind: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

/// One replica's bounded event ring. Cheap to clone (`Arc`) into the
/// replica thread; readable from any thread at dump time.
pub struct Ring {
    slots: Box<[Slot]>,
    cursor: AtomicU64,
}

impl Ring {
    pub fn new() -> Arc<Ring> {
        Arc::new(Ring {
            slots: (0..RING_CAP)
                .map(|_| Slot {
                    seq: AtomicU64::new(0),
                    t_us: AtomicU64::new(0),
                    kind: AtomicU64::new(0),
                    a: AtomicU64::new(0),
                    b: AtomicU64::new(0),
                })
                .collect(),
            cursor: AtomicU64::new(0),
        })
    }

    /// Record an event at clock time `t_us`. Lock-free; oldest events
    /// are overwritten once the ring wraps.
    pub fn push(&self, t_us: u64, ev: Event) {
        if !enabled() {
            return;
        }
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(i as usize) % RING_CAP];
        let (kind, a, b) = ev.encode();
        // Seqlock: odd stamp while writing, even (2i+2) when complete.
        slot.seq.store(2 * i + 1, Ordering::Release);
        slot.t_us.store(t_us, Ordering::Relaxed);
        slot.kind.store(kind, Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        slot.seq.store(2 * i + 2, Ordering::Release);
    }

    /// Total events ever pushed (≥ `events().len()`).
    pub fn pushed(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// The retained events, oldest first. Torn slots (a write racing
    /// this read) are skipped.
    pub fn events(&self) -> Vec<(u64, Event)> {
        let end = self.cursor.load(Ordering::Acquire);
        let start = end.saturating_sub(RING_CAP as u64);
        let mut out = Vec::new();
        for i in start..end {
            let slot = &self.slots[(i as usize) % RING_CAP];
            let seq0 = slot.seq.load(Ordering::Acquire);
            if seq0 != 2 * i + 2 {
                continue; // torn or already overwritten
            }
            let t = slot.t_us.load(Ordering::Relaxed);
            let kind = slot.kind.load(Ordering::Relaxed);
            let a = slot.a.load(Ordering::Relaxed);
            let b = slot.b.load(Ordering::Relaxed);
            if slot.seq.load(Ordering::Acquire) != seq0 {
                continue;
            }
            if let Some(ev) = Event::decode(kind, a, b) {
                out.push((t, ev));
            }
        }
        out
    }
}

/// Registry of per-replica rings for one server pool, plus the dump
/// machinery. Owned by the pool (`Arc`), shared with the watchdog and
/// crash guards.
#[derive(Default)]
pub struct FlightRecorder {
    rings: Mutex<Vec<(usize, Arc<Ring>)>>,
}

impl FlightRecorder {
    pub fn new() -> Arc<FlightRecorder> {
        Arc::new(FlightRecorder::default())
    }

    /// Create and register the ring for `replica`. Ids are never
    /// reused, so one ring per id for the pool's lifetime.
    pub fn ring(&self, replica: usize) -> Arc<Ring> {
        let ring = Ring::new();
        let mut rings = self.rings.lock().unwrap_or_else(|e| e.into_inner());
        rings.push((replica, ring.clone()));
        ring
    }

    /// The already-registered ring for `replica`, if any — how the
    /// watchdog (which never spawned the replica) attributes a steal to
    /// the wedged owner's timeline.
    pub fn existing(&self, replica: usize) -> Option<Arc<Ring>> {
        let rings = self.rings.lock().unwrap_or_else(|e| e.into_inner());
        rings.iter().find(|(r, _)| *r == replica).map(|(_, ring)| Arc::clone(ring))
    }

    /// Render every ring (oldest event first, replicas in spawn order).
    pub fn render(&self) -> String {
        let rings = self.rings.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = String::new();
        for (replica, ring) in rings.iter() {
            for (t_us, ev) in ring.events() {
                out.push_str(&format!("[flight] t_us={t_us} replica={replica} {}\n", ev.render()));
            }
        }
        out
    }

    /// Dump every ring to stderr with a reason header, and retain the
    /// text for tests (`last_dump`). Called on organic panic, watchdog
    /// steal and `shutdown_all`; `quiet` suppresses stderr (the clean
    /// shutdown path records for tests without spamming CI logs).
    pub fn dump(&self, why: &str, quiet: bool) -> String {
        let body = self.render();
        let text = format!("[flight] --- dump: {why} ---\n{body}[flight] --- end dump ---\n");
        if !quiet && !body.is_empty() {
            eprint!("{text}");
        }
        let mut last = last_dump_cell().lock().unwrap_or_else(|e| e.into_inner());
        *last = Some(text.clone());
        text
    }
}

fn last_dump_cell() -> &'static Mutex<Option<String>> {
    static CELL: OnceLock<Mutex<Option<String>>> = OnceLock::new();
    CELL.get_or_init(|| Mutex::new(None))
}

/// The most recent dump text, process-wide (test hook).
pub fn last_dump() -> Option<String> {
    last_dump_cell().lock().unwrap_or_else(|e| e.into_inner()).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn ring_wraps_keeping_the_newest_cap_events() {
        let _guard = crate::obs::test_lock();
        let ring = Ring::new();
        for i in 0..(RING_CAP as u64 + 10) {
            ring.push(i, Event::Train { cut: i });
        }
        let evs = ring.events();
        assert_eq!(evs.len(), RING_CAP);
        assert_eq!(evs[0], (10, Event::Train { cut: 10 }));
        assert_eq!(
            evs[RING_CAP - 1],
            (RING_CAP as u64 + 9, Event::Train { cut: RING_CAP as u64 + 9 })
        );
        assert_eq!(ring.pushed(), RING_CAP as u64 + 10);
    }

    #[test]
    fn every_event_round_trips_through_the_encoding() {
        let all = [
            Event::ReplicaStart,
            Event::ReplicaExit,
            Event::Flush { why: FlushWhy::Full, batch: 8 },
            Event::Flush { why: FlushWhy::MaxWait, batch: 3 },
            Event::Flush { why: FlushWhy::Idle, batch: 2 },
            Event::Flush { why: FlushWhy::Fence, batch: 0 },
            Event::Flush { why: FlushWhy::Closed, batch: 1 },
            Event::Flush { why: FlushWhy::Replay, batch: 4 },
            Event::BarrierEnter,
            Event::BarrierQuiesced,
            Event::BarrierResume { spawned: 1 },
            Event::FaultPanic,
            Event::FaultStall,
            Event::Stolen { jobs: 4 },
            Event::Resync { diff: true, bytes: 123 },
            Event::Resync { diff: false, bytes: 99_999 },
            Event::Train { cut: 17 },
        ];
        for ev in all {
            let (k, a, b) = ev.encode();
            assert_eq!(Event::decode(k, a, b), Some(ev));
            assert!(ev.render().starts_with("event="));
        }
    }

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn concurrent_pushes_stay_decodable() {
        let _guard = crate::obs::test_lock();
        let ring = Ring::new();
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let ring = &ring;
                scope.spawn(move || {
                    for i in 0..500 {
                        ring.push(t * 1000 + i, Event::Stolen { jobs: i });
                    }
                });
            }
        });
        // All retained slots must decode (no torn writes once quiesced).
        assert_eq!(ring.events().len(), RING_CAP);
    }

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn dump_renders_and_is_retained() {
        let _guard = crate::obs::test_lock();
        let rec = FlightRecorder::new();
        let ring = rec.ring(7);
        ring.push(5, Event::FaultPanic);
        let text = rec.dump("unit test", true);
        assert!(text.contains("replica=7"));
        assert!(text.contains("event=fault_panic"));
        assert_eq!(last_dump().as_deref(), Some(text.as_str()));
    }
}
