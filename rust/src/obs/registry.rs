//! Process-wide metric registry: named counters, gauges and log2
//! latency histograms.
//!
//! Registration is lazy and idempotent — `counter("name")` returns the
//! existing handle or creates one — and hands back `&'static` handles
//! so hot paths register once (in a constructor or a `OnceLock`) and
//! then increment with zero lookups and zero locks. The registry's own
//! maps are only locked at registration and export time.
//!
//! Naming convention (see the README metric table): Prometheus-style
//! `snake_case` bases with optional `{key="value",...}` label suffixes
//! baked into the registered name, e.g.
//! `serve_stage_us{stage="compute",lane="interactive"}`. The exporter
//! splits the label block back out, so labeled series render as proper
//! Prometheus labels; the JSON snapshot keeps the full string as the
//! key. Metric handles live for the process lifetime (they are
//! intentionally leaked — the set of metric names is small and static).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use super::hist::{HistSnapshot, Histogram};
use super::{enabled, shard_index};

/// Shards per counter (power of two, mask-selected).
const SHARDS: usize = 16;

#[repr(align(64))]
struct PaddedU64(AtomicU64);

/// Monotone counter, sharded so concurrent hot-path increments from
/// different workers land on different cache lines.
pub struct Counter {
    shards: Box<[PaddedU64]>,
}

impl Counter {
    fn new() -> Counter {
        Counter { shards: (0..SHARDS).map(|_| PaddedU64(AtomicU64::new(0))).collect() }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if !enabled() {
            return;
        }
        self.shards[shard_index() & (SHARDS - 1)].0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Point-in-time value (pool live workers, live replicas, queue depth).
/// Gauges are set on state transitions — low-rate by construction — so
/// a single atomic cell is enough.
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge { v: AtomicI64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: i64) {
        if !enabled() {
            return;
        }
        self.v.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        if !enabled() {
            return;
        }
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

#[derive(Default)]
struct Maps {
    counters: BTreeMap<String, &'static Counter>,
    gauges: BTreeMap<String, &'static Gauge>,
    hists: BTreeMap<String, &'static Histogram>,
}

static MAPS: OnceLock<Mutex<Maps>> = OnceLock::new();

fn maps() -> &'static Mutex<Maps> {
    MAPS.get_or_init(|| Mutex::new(Maps::default()))
}

/// Get-or-register a counter. Call once and keep the handle.
pub fn counter(name: &str) -> &'static Counter {
    let mut m = maps().lock().unwrap_or_else(|e| e.into_inner());
    m.counters
        .entry(name.to_string())
        .or_insert_with(|| Box::leak(Box::new(Counter::new())))
}

/// Get-or-register a gauge.
pub fn gauge(name: &str) -> &'static Gauge {
    let mut m = maps().lock().unwrap_or_else(|e| e.into_inner());
    m.gauges
        .entry(name.to_string())
        .or_insert_with(|| Box::leak(Box::new(Gauge::new())))
}

/// Get-or-register a histogram of µs values.
pub fn histogram(name: &str) -> &'static Histogram {
    let mut m = maps().lock().unwrap_or_else(|e| e.into_inner());
    m.hists
        .entry(name.to_string())
        .or_insert_with(|| Box::leak(Box::new(Histogram::new())))
}

/// Record into a histogram, gated on the kill-switch (for call sites
/// that hold the handle; histograms themselves don't re-check).
#[inline]
pub fn record_us(h: &Histogram, us: u64) {
    if enabled() {
        h.record_us(us);
    }
}

/// Count one blocked-GEMM dispatch into the shared engine counters
/// (`gemm_calls_total`, `gemm_macs_total`) — used by both the f32 and
/// the integer GEMM cores. Handles resolve once; each call after that
/// is two relaxed sharded adds, a no-op under the kill-switch.
#[inline]
pub fn count_gemm(macs: u64) {
    static CELLS: OnceLock<(&'static Counter, &'static Counter)> = OnceLock::new();
    let (calls, total_macs) =
        CELLS.get_or_init(|| (counter("gemm_calls_total"), counter("gemm_macs_total")));
    calls.inc();
    total_macs.add(macs);
}

/// A point-in-time copy of every registered metric, name-sorted (the
/// maps are BTreeMaps), suitable for export.
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub hists: Vec<(String, HistSnapshot)>,
}

/// Snapshot the whole registry.
pub fn snapshot() -> MetricsSnapshot {
    let m = maps().lock().unwrap_or_else(|e| e.into_inner());
    MetricsSnapshot {
        counters: m.counters.iter().map(|(k, c)| (k.clone(), c.get())).collect(),
        gauges: m.gauges.iter().map(|(k, g)| (k.clone(), g.get())).collect(),
        hists: m.hists.iter().map(|(k, h)| (k.clone(), h.snapshot())).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Count-asserting tests are meaningless when the hooks are
    // compiled out.
    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn registration_is_idempotent_and_handles_are_stable() {
        let _guard = crate::obs::test_lock();
        let a = counter("test_registry_idempotent_total");
        let b = counter("test_registry_idempotent_total");
        assert!(std::ptr::eq(a, b));
        a.add(3);
        b.add(4);
        assert_eq!(a.get(), 7);
    }

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn concurrent_increments_never_lose_counts() {
        let _guard = crate::obs::test_lock();
        let c = counter("test_registry_concurrent_total");
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn gauges_set_and_drift() {
        let _guard = crate::obs::test_lock();
        let g = gauge("test_registry_gauge");
        g.set(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
    }

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn snapshot_sees_registered_metrics() {
        let _guard = crate::obs::test_lock();
        counter("test_registry_snapshot_total").add(1);
        histogram("test_registry_snapshot_us").record_us(42);
        let snap = snapshot();
        assert!(snap.counters.iter().any(|(k, v)| k == "test_registry_snapshot_total" && *v >= 1));
        assert!(snap.hists.iter().any(|(k, h)| k == "test_registry_snapshot_us" && h.count >= 1));
    }
}
