//! Metric exporters: Prometheus text exposition and a JSON snapshot.
//!
//! Both render [`registry::snapshot`]. Names may carry a baked-in label
//! block (`serve_stage_us{stage="compute",lane="interactive"}`); the
//! Prometheus emitter splits it back out so histogram `le` labels can
//! be appended inside the braces, while the JSON emitter keeps the full
//! string as the object key (it is already unambiguous there).
//!
//! Histograms render the Prometheus way: cumulative `_bucket{le="..."}`
//! series over the log2 upper bounds (only non-empty buckets, plus the
//! mandatory `+Inf`), `_sum`, `_count`, and a non-standard `_max` gauge
//! (exact, from the histogram side-channel). The JSON form carries the
//! derived summary (count/sum/mean/p50/p95/p99/max) plus the sparse
//! buckets, which is what the bench reports embed.

use crate::util::json::{Json, Obj};

use super::hist::{bucket_hi, HistSnapshot};
use super::registry::{self, MetricsSnapshot};

/// Split `name{labels}` into `(name, Some("labels"))` or `(name, None)`.
fn split_labels(name: &str) -> (&str, Option<&str>) {
    match (name.find('{'), name.rfind('}')) {
        (Some(open), Some(close)) if close > open => {
            (&name[..open], Some(&name[open + 1..close]))
        }
        _ => (name, None),
    }
}

fn prom_series(base: &str, labels: Option<&str>, extra: Option<&str>) -> String {
    match (labels, extra) {
        (None, None) => base.to_string(),
        (Some(l), None) => format!("{base}{{{l}}}"),
        (None, Some(e)) => format!("{base}{{{e}}}"),
        (Some(l), Some(e)) => format!("{base}{{{l},{e}}}"),
    }
}

/// Render the whole registry in Prometheus text exposition format.
pub fn prometheus() -> String {
    render_prometheus(&registry::snapshot())
}

fn render_prometheus(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let (base, labels) = split_labels(name);
        out.push_str(&format!("# TYPE {base} counter\n"));
        out.push_str(&format!("{} {v}\n", prom_series(base, labels, None)));
    }
    for (name, v) in &snap.gauges {
        let (base, labels) = split_labels(name);
        out.push_str(&format!("# TYPE {base} gauge\n"));
        out.push_str(&format!("{} {v}\n", prom_series(base, labels, None)));
    }
    for (name, h) in &snap.hists {
        let (base, labels) = split_labels(name);
        out.push_str(&format!("# TYPE {base} histogram\n"));
        let mut cum = 0u64;
        for (i, n) in h.buckets.iter().enumerate() {
            if *n == 0 {
                continue;
            }
            cum += n;
            let le = format!("le=\"{}\"", bucket_hi(i));
            out.push_str(&format!("{} {cum}\n", prom_series(&format!("{base}_bucket"), labels, Some(&le))));
        }
        out.push_str(&format!(
            "{} {}\n",
            prom_series(&format!("{base}_bucket"), labels, Some("le=\"+Inf\"")),
            h.count
        ));
        out.push_str(&format!("{} {}\n", prom_series(&format!("{base}_sum"), labels, None), h.sum));
        out.push_str(&format!("{} {}\n", prom_series(&format!("{base}_count"), labels, None), h.count));
        out.push_str(&format!("{} {}\n", prom_series(&format!("{base}_max"), labels, None), h.max));
    }
    out
}

/// One histogram as a JSON object: derived summary + sparse buckets.
pub fn hist_json(h: &HistSnapshot) -> Json {
    let mut buckets = Obj::new();
    for (i, n) in h.buckets.iter().enumerate() {
        if *n > 0 {
            buckets.put(&format!("le_{}", bucket_hi(i)), *n);
        }
    }
    let mut o = Obj::new();
    o.put("count", h.count);
    o.put("sum_us", h.sum);
    o.put("mean_us", Json::fixed(h.mean_us(), 1));
    o.put("p50_us", Json::fixed(h.quantile_us(0.50), 1));
    o.put("p95_us", Json::fixed(h.quantile_us(0.95), 1));
    o.put("p99_us", Json::fixed(h.quantile_us(0.99), 1));
    o.put("max_us", h.max);
    o.put("buckets", buckets.build());
    o.build()
}

/// The whole registry as a JSON value (embed in bench reports) —
/// `{"counters": {...}, "gauges": {...}, "histograms": {...}}`.
pub fn json_value() -> Json {
    let snap = registry::snapshot();
    let mut counters = Obj::new();
    for (name, v) in &snap.counters {
        counters.put(name, *v);
    }
    let mut gauges = Obj::new();
    for (name, v) in &snap.gauges {
        gauges.put(name, *v);
    }
    let mut hists = Obj::new();
    for (name, h) in &snap.hists {
        hists.put(name, hist_json(h));
    }
    let mut o = Obj::new();
    o.put("counters", counters.build());
    o.put("gauges", gauges.build());
    o.put("histograms", hists.build());
    o.build()
}

/// The whole registry as a pretty-printed JSON document
/// (`--metrics-json PATH`, `tinycl obs-report --format json`).
pub fn json_snapshot() -> String {
    json_value().to_pretty(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_blocks_split_and_recombine() {
        assert_eq!(split_labels("plain_total"), ("plain_total", None));
        assert_eq!(
            split_labels("serve_stage_us{stage=\"compute\",lane=\"bulk\"}"),
            ("serve_stage_us", Some("stage=\"compute\",lane=\"bulk\""))
        );
        assert_eq!(
            prom_series("x_bucket", Some("lane=\"bulk\""), Some("le=\"8\"")),
            "x_bucket{lane=\"bulk\",le=\"8\"}"
        );
    }

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn prometheus_renders_registered_metrics() {
        let _guard = crate::obs::test_lock();
        registry::counter("test_export_total{lane=\"interactive\"}").add(2);
        registry::histogram("test_export_us").record_us(100);
        let text = prometheus();
        assert!(text.contains("# TYPE test_export_total counter"));
        assert!(text.contains("test_export_total{lane=\"interactive\"} 2"));
        assert!(text.contains("# TYPE test_export_us histogram"));
        assert!(text.contains("test_export_us_bucket{le=\"128\"} 1"));
        assert!(text.contains("test_export_us_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("test_export_us_sum 100"));
        assert!(text.contains("test_export_us_count 1"));
    }

    #[cfg(not(feature = "obs-off"))]
    #[test]
    fn json_snapshot_is_a_valid_document() {
        let _guard = crate::obs::test_lock();
        registry::counter("test_export_json_total").add(1);
        registry::histogram("test_export_json_us").record_us(5);
        let s = json_snapshot();
        assert!(s.contains("\"counters\""));
        assert!(s.contains("\"test_export_json_total\""));
        assert!(s.contains("\"le_8\": 1"));
        // Crude structural check: balanced braces, ends with newline.
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert!(s.ends_with('\n'));
    }
}
