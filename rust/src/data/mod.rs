//! Dataset substrate.
//!
//! The paper trains on CIFAR-10; this environment has no network access,
//! so we substitute a deterministic synthetic 32×32×3 10-class dataset
//! (see DESIGN.md substitution table). What the CL experiments need from
//! CIFAR-10 is: (a) 10 visually distinct classes, (b) enough within-class
//! variation that memorization ≠ generalization, (c) class-incremental
//! splits, (d) learnability by the paper's small Conv-Conv-Dense model.
//! The generator provides all four with seeded, reproducible sampling.

mod synthetic;

pub use synthetic::{
    splitmix64, task_class_partition, Dataset, Sample, SyntheticCifar, TaskSchedule,
};

use crate::fixed::Fx;
use crate::tensor::Tensor;

/// Quantize a float sample into the accelerator's input domain.
pub fn quantize_sample(x: &Tensor<f32>) -> Tensor<Fx> {
    crate::tensor::quantize_tensor(x)
}
