//! Deterministic synthetic CIFAR-10-like dataset.
//!
//! Class signal is a mixture of (a) a class-specific 2-D sinusoidal
//! texture (frequency/orientation pair per class), (b) a class-colored
//! radial blob at a class-dependent position, and (c) a per-channel bias.
//! Per-sample variation: random phase shifts, blob jitter, amplitude
//! jitter, and additive Gaussian noise. With the default noise level the
//! paper's model reaches well above chance but below 100% — enough
//! head-room for CL forgetting effects to be visible.

use crate::tensor::{Shape, Tensor};
use crate::util::rng::Pcg32;

/// One labelled image (CHW float in [-1, 1]).
#[derive(Clone, Debug)]
pub struct Sample {
    pub x: Tensor<f32>,
    pub label: usize,
}

/// A split (train or test) with per-class indices.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub samples: Vec<Sample>,
    pub num_classes: usize,
    by_class: Vec<Vec<usize>>,
}

impl Dataset {
    pub fn new(samples: Vec<Sample>, num_classes: usize) -> Dataset {
        let mut by_class = vec![Vec::new(); num_classes];
        for (i, s) in samples.iter().enumerate() {
            by_class[s.label].push(i);
        }
        Dataset { samples, num_classes, by_class }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Indices of all samples with the given label.
    pub fn class_indices(&self, label: usize) -> &[usize] {
        &self.by_class[label]
    }

    /// Samples whose label is in `classes` (a task's slice of the data).
    pub fn task_subset(&self, classes: &[usize]) -> Vec<&Sample> {
        self.samples
            .iter()
            .filter(|s| classes.contains(&s.label))
            .collect()
    }
}

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct SyntheticCifar {
    pub image_size: usize,
    pub channels: usize,
    pub num_classes: usize,
    /// Additive Gaussian noise σ (signal amplitude is ~1).
    pub noise: f32,
    pub seed: u64,
}

impl Default for SyntheticCifar {
    fn default() -> Self {
        SyntheticCifar { image_size: 32, channels: 3, num_classes: 10, noise: 0.35, seed: 7 }
    }
}

impl SyntheticCifar {
    /// Generate `per_class` samples per class. `split` disambiguates
    /// train/test streams (disjoint RNG streams ⇒ disjoint samples).
    pub fn generate(&self, per_class: usize, split: u64) -> Dataset {
        let mut samples = Vec::with_capacity(per_class * self.num_classes);
        for label in 0..self.num_classes {
            let mut rng = Pcg32::new(
                self.seed ^ (split.wrapping_mul(0x9E3779B97F4A7C15)),
                (label as u64 + 1) << 8,
            );
            for _ in 0..per_class {
                samples.push(Sample { x: self.render(label, &mut rng), label });
            }
        }
        Dataset::new(samples, self.num_classes)
    }

    /// Render one sample of `label`.
    fn render(&self, label: usize, rng: &mut Pcg32) -> Tensor<f32> {
        let n = self.image_size;
        let mut img = Tensor::zeros(Shape::d3(self.channels, n, n));

        // Class-specific texture parameters.
        let fx = 1.0 + (label % 5) as f32; // cycles across the image
        let fy = 1.0 + (label / 5) as f32 * 2.0;
        let theta = label as f32 * std::f32::consts::PI / 10.0;
        let (st, ct) = theta.sin_cos();

        // Class-specific blob.
        let bx0 = 0.25 + 0.5 * ((label * 37 % 10) as f32 / 9.0);
        let by0 = 0.25 + 0.5 * ((label * 53 % 10) as f32 / 9.0);

        // Per-sample jitter.
        let phase = rng.range_f32(0.0, 2.0 * std::f32::consts::PI);
        let amp = rng.range_f32(0.7, 1.0);
        let bx = bx0 + rng.range_f32(-0.08, 0.08);
        let by = by0 + rng.range_f32(-0.08, 0.08);
        let bsig = rng.range_f32(0.10, 0.16);

        for c in 0..self.channels {
            // Class- and channel-dependent mixing weights.
            let wt = 0.6 + 0.4 * (((label + c) % 3) as f32 / 2.0);
            let bias = ((label as f32 / self.num_classes as f32) - 0.5)
                * if c == label % self.channels { 0.6 } else { 0.2 };
            for y in 0..n {
                for x in 0..n {
                    let u = x as f32 / n as f32;
                    let v = y as f32 / n as f32;
                    // rotated sinusoidal texture
                    let ur = u * ct - v * st;
                    let vr = u * st + v * ct;
                    let tex = (2.0 * std::f32::consts::PI * (fx * ur + fy * vr) + phase).sin();
                    // radial blob (class-colored: sign alternates per channel)
                    let d2 = (u - bx) * (u - bx) + (v - by) * (v - by);
                    let blob = (-d2 / (2.0 * bsig * bsig)).exp()
                        * if (label + c) % 2 == 0 { 1.0 } else { -1.0 };
                    let noise = rng.normal() * self.noise;
                    let val = amp * (wt * tex * 0.5 + blob * 0.8) + bias + noise;
                    img.set3(c, y, x, val.clamp(-1.0, 1.0));
                }
            }
        }
        img
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let gen = SyntheticCifar::default();
        let a = gen.generate(2, 0);
        let b = gen.generate(2, 0);
        assert_eq!(a.len(), 20);
        for (sa, sb) in a.samples.iter().zip(&b.samples) {
            assert_eq!(sa.label, sb.label);
            assert_eq!(sa.x.data(), sb.x.data());
        }
    }

    #[test]
    fn splits_are_disjoint() {
        let gen = SyntheticCifar::default();
        let train = gen.generate(1, 0);
        let test = gen.generate(1, 1);
        for (a, b) in train.samples.iter().zip(&test.samples) {
            assert_ne!(a.x.data(), b.x.data(), "train/test leakage");
        }
    }

    #[test]
    fn values_in_range_and_nontrivial() {
        let gen = SyntheticCifar::default();
        let d = gen.generate(3, 0);
        for s in &d.samples {
            assert!(s.x.data().iter().all(|v| (-1.0..=1.0).contains(v)));
            let spread = s.x.data().iter().cloned().fold(f32::MIN, f32::max)
                - s.x.data().iter().cloned().fold(f32::MAX, f32::min);
            assert!(spread > 0.5, "degenerate image (spread {spread})");
        }
    }

    #[test]
    fn class_indices_partition() {
        let gen = SyntheticCifar::default();
        let d = gen.generate(4, 0);
        let total: usize = (0..10).map(|c| d.class_indices(c).len()).sum();
        assert_eq!(total, d.len());
        for c in 0..10 {
            assert_eq!(d.class_indices(c).len(), 4);
            for &i in d.class_indices(c) {
                assert_eq!(d.samples[i].label, c);
            }
        }
    }

    #[test]
    fn task_subset_filters() {
        let gen = SyntheticCifar::default();
        let d = gen.generate(2, 0);
        let t = d.task_subset(&[0, 1]);
        assert_eq!(t.len(), 4);
        assert!(t.iter().all(|s| s.label < 2));
    }

    #[test]
    fn classes_are_separable_by_simple_statistic() {
        // Per-class channel means should differ between at least some
        // class pairs — a sanity floor for learnability.
        let gen = SyntheticCifar::default();
        let d = gen.generate(8, 0);
        let mean_of = |c: usize| -> f32 {
            let idx = d.class_indices(c);
            idx.iter()
                .map(|&i| {
                    let s = &d.samples[i];
                    s.x.data().iter().sum::<f32>() / s.x.data().len() as f32
                })
                .sum::<f32>()
                / idx.len() as f32
        };
        let m0 = mean_of(0);
        let m9 = mean_of(9);
        assert!((m0 - m9).abs() > 0.05, "classes statistically identical");
    }

    #[test]
    fn learnable_by_tiny_model() {
        // A small f32 model should fit a handful of samples from 2 classes
        // well above chance within a few epochs.
        use crate::nn::{Model, ModelConfig};
        let gen = SyntheticCifar { image_size: 16, ..Default::default() };
        let d = gen.generate(10, 0);
        let task: Vec<&Sample> = d.task_subset(&[0, 1]);
        let cfg = ModelConfig {
            in_channels: 3,
            image_size: 16,
            conv_channels: 4,
            num_classes: 10,
            grad_clip: 1.0,
        };
        let mut m = Model::new(cfg, 11);
        for _ in 0..6 {
            for s in &task {
                m.train_step(&s.x, s.label, 2, 0.05);
            }
        }
        let acc = task
            .iter()
            .filter(|s| m.predict(&s.x, 2) == s.label)
            .count() as f32
            / task.len() as f32;
        assert!(acc >= 0.8, "train accuracy {acc} < 0.8 on 2-class subset");
    }
}
