//! Deterministic synthetic CIFAR-10-like dataset.
//!
//! Class signal is a mixture of (a) a class-specific 2-D sinusoidal
//! texture (frequency/orientation pair per class), (b) a class-colored
//! radial blob at a class-dependent position, and (c) a per-channel bias.
//! Per-sample variation: random phase shifts, blob jitter, amplitude
//! jitter, and additive Gaussian noise. With the default noise level the
//! paper's model reaches well above chance but below 100% — enough
//! head-room for CL forgetting effects to be visible.

use crate::tensor::{Shape, Tensor};
use crate::util::rng::Pcg32;

/// One labelled image (CHW float in [-1, 1]).
#[derive(Clone, Debug)]
pub struct Sample {
    pub x: Tensor<f32>,
    pub label: usize,
}

/// A split (train or test) with per-class indices.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub samples: Vec<Sample>,
    pub num_classes: usize,
    by_class: Vec<Vec<usize>>,
}

impl Dataset {
    pub fn new(samples: Vec<Sample>, num_classes: usize) -> Dataset {
        let mut by_class = vec![Vec::new(); num_classes];
        for (i, s) in samples.iter().enumerate() {
            by_class[s.label].push(i);
        }
        Dataset { samples, num_classes, by_class }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Indices of all samples with the given label.
    pub fn class_indices(&self, label: usize) -> &[usize] {
        &self.by_class[label]
    }

    /// Samples whose label is in `classes` (a task's slice of the data).
    pub fn task_subset(&self, classes: &[usize]) -> Vec<&Sample> {
        self.samples
            .iter()
            .filter(|s| classes.contains(&s.label))
            .collect()
    }
}

/// SplitMix64: one 64-bit hash step per index. Stateless (any index is
/// addressable directly), trivially mirrored by the pure-Python
/// differential tests — the seed substrate for the task-stream
/// generators below.
pub fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Disjoint near-equal class partition for a K-task class-incremental
/// split: classes are shuffled (Fisher–Yates over [`splitmix64`]) then
/// chunked, the first `num_classes % num_tasks` tasks taking one extra
/// class. Same seed ⇒ same partition; every class lands in exactly one
/// task.
pub fn task_class_partition(num_classes: usize, num_tasks: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(num_tasks > 0, "partition needs at least one task");
    assert!(
        num_tasks <= num_classes,
        "cannot split {num_classes} classes across {num_tasks} tasks"
    );
    let mut classes: Vec<usize> = (0..num_classes).collect();
    for i in (1..num_classes).rev() {
        let j = (splitmix64(seed ^ i as u64) % (i as u64 + 1)) as usize;
        classes.swap(i, j);
    }
    let base = num_classes / num_tasks;
    let extra = num_classes % num_tasks;
    let mut parts = Vec::with_capacity(num_tasks);
    let mut at = 0;
    for t in 0..num_tasks {
        let take = base + usize::from(t < extra);
        parts.push(classes[at..at + take].to_vec());
        at += take;
    }
    parts
}

/// How a request stream interleaves its tasks — the task-incremental
/// generators driving the multi-task serve rung and its tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskSchedule {
    /// Request i → task `i % K`: maximal interleaving, every coalesced
    /// batch mixes tasks (the shared-backbone router's worst case).
    RoundRobin,
    /// Contiguous task blocks (`i·K/n`): the classic task-incremental
    /// stream — one task at a time, a hard switch between them.
    Blocked,
    /// Seeded uniform task draw per request ([`splitmix64`] on the
    /// index): same seed ⇒ same schedule.
    Random,
}

impl TaskSchedule {
    pub fn parse(s: &str) -> Option<TaskSchedule> {
        match s {
            "roundrobin" => Some(TaskSchedule::RoundRobin),
            "blocked" => Some(TaskSchedule::Blocked),
            "random" => Some(TaskSchedule::Random),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskSchedule::RoundRobin => "roundrobin",
            TaskSchedule::Blocked => "blocked",
            TaskSchedule::Random => "random",
        }
    }

    /// Task id for request `i` of a stream of `n` across `k` tasks.
    /// Pure in (i, n, k, seed) — any position is addressable without
    /// generating its prefix, so concurrent load clients stay
    /// deterministic.
    pub fn task_for(&self, i: usize, n: usize, k: usize, seed: u64) -> usize {
        assert!(k > 0, "schedule needs at least one task");
        match self {
            TaskSchedule::RoundRobin => i % k,
            TaskSchedule::Blocked => {
                if n == 0 {
                    0
                } else {
                    ((i * k) / n).min(k - 1)
                }
            }
            TaskSchedule::Random => {
                let h = splitmix64(seed ^ (i as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93));
                (h % k as u64) as usize
            }
        }
    }
}

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct SyntheticCifar {
    pub image_size: usize,
    pub channels: usize,
    pub num_classes: usize,
    /// Additive Gaussian noise σ (signal amplitude is ~1).
    pub noise: f32,
    pub seed: u64,
}

impl Default for SyntheticCifar {
    fn default() -> Self {
        SyntheticCifar { image_size: 32, channels: 3, num_classes: 10, noise: 0.35, seed: 7 }
    }
}

impl SyntheticCifar {
    /// Generate `per_class` samples per class. `split` disambiguates
    /// train/test streams (disjoint RNG streams ⇒ disjoint samples).
    pub fn generate(&self, per_class: usize, split: u64) -> Dataset {
        let mut samples = Vec::with_capacity(per_class * self.num_classes);
        for label in 0..self.num_classes {
            let mut rng = Pcg32::new(
                self.seed ^ (split.wrapping_mul(0x9E3779B97F4A7C15)),
                (label as u64 + 1) << 8,
            );
            for _ in 0..per_class {
                samples.push(Sample { x: self.render(label, &mut rng), label });
            }
        }
        Dataset::new(samples, self.num_classes)
    }

    /// Render one sample of `label`.
    fn render(&self, label: usize, rng: &mut Pcg32) -> Tensor<f32> {
        let n = self.image_size;
        let mut img = Tensor::zeros(Shape::d3(self.channels, n, n));

        // Class-specific texture parameters.
        let fx = 1.0 + (label % 5) as f32; // cycles across the image
        let fy = 1.0 + (label / 5) as f32 * 2.0;
        let theta = label as f32 * std::f32::consts::PI / 10.0;
        let (st, ct) = theta.sin_cos();

        // Class-specific blob.
        let bx0 = 0.25 + 0.5 * ((label * 37 % 10) as f32 / 9.0);
        let by0 = 0.25 + 0.5 * ((label * 53 % 10) as f32 / 9.0);

        // Per-sample jitter.
        let phase = rng.range_f32(0.0, 2.0 * std::f32::consts::PI);
        let amp = rng.range_f32(0.7, 1.0);
        let bx = bx0 + rng.range_f32(-0.08, 0.08);
        let by = by0 + rng.range_f32(-0.08, 0.08);
        let bsig = rng.range_f32(0.10, 0.16);

        for c in 0..self.channels {
            // Class- and channel-dependent mixing weights.
            let wt = 0.6 + 0.4 * (((label + c) % 3) as f32 / 2.0);
            let bias = ((label as f32 / self.num_classes as f32) - 0.5)
                * if c == label % self.channels { 0.6 } else { 0.2 };
            for y in 0..n {
                for x in 0..n {
                    let u = x as f32 / n as f32;
                    let v = y as f32 / n as f32;
                    // rotated sinusoidal texture
                    let ur = u * ct - v * st;
                    let vr = u * st + v * ct;
                    let tex = (2.0 * std::f32::consts::PI * (fx * ur + fy * vr) + phase).sin();
                    // radial blob (class-colored: sign alternates per channel)
                    let d2 = (u - bx) * (u - bx) + (v - by) * (v - by);
                    let blob = (-d2 / (2.0 * bsig * bsig)).exp()
                        * if (label + c) % 2 == 0 { 1.0 } else { -1.0 };
                    let noise = rng.normal() * self.noise;
                    let val = amp * (wt * tex * 0.5 + blob * 0.8) + bias + noise;
                    img.set3(c, y, x, val.clamp(-1.0, 1.0));
                }
            }
        }
        img
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_disjoint_exhaustive_and_seeded() {
        for &(c, k) in &[(10usize, 3usize), (10, 10), (4, 3), (7, 2)] {
            let a = task_class_partition(c, k, 42);
            let b = task_class_partition(c, k, 42);
            assert_eq!(a, b, "same seed must give the same partition");
            let mut seen: Vec<usize> = a.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..c).collect::<Vec<_>>(), "({c},{k}) not a partition");
            let sizes: Vec<usize> = a.iter().map(Vec::len).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "({c},{k}) sizes unbalanced: {sizes:?}");
        }
        assert_ne!(
            task_class_partition(10, 3, 1),
            task_class_partition(10, 3, 2),
            "different seeds should shuffle differently"
        );
    }

    #[test]
    fn schedules_are_deterministic_and_cover_tasks() {
        let (n, k) = (60, 3);
        for sched in [TaskSchedule::RoundRobin, TaskSchedule::Blocked, TaskSchedule::Random] {
            let a: Vec<usize> = (0..n).map(|i| sched.task_for(i, n, k, 9)).collect();
            let b: Vec<usize> = (0..n).map(|i| sched.task_for(i, n, k, 9)).collect();
            assert_eq!(a, b, "{} not seed-deterministic", sched.name());
            assert!(a.iter().all(|&t| t < k));
            for t in 0..k {
                assert!(a.contains(&t), "{} never scheduled task {t}", sched.name());
            }
            assert_eq!(TaskSchedule::parse(sched.name()), Some(sched));
        }
        // Blocked = contiguous non-decreasing runs; roundrobin cycles.
        let blocked: Vec<usize> =
            (0..n).map(|i| TaskSchedule::Blocked.task_for(i, n, k, 0)).collect();
        assert!(blocked.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(TaskSchedule::RoundRobin.task_for(7, n, k, 0), 1);
    }

    #[test]
    fn generation_is_deterministic() {
        let gen = SyntheticCifar::default();
        let a = gen.generate(2, 0);
        let b = gen.generate(2, 0);
        assert_eq!(a.len(), 20);
        for (sa, sb) in a.samples.iter().zip(&b.samples) {
            assert_eq!(sa.label, sb.label);
            assert_eq!(sa.x.data(), sb.x.data());
        }
    }

    #[test]
    fn splits_are_disjoint() {
        let gen = SyntheticCifar::default();
        let train = gen.generate(1, 0);
        let test = gen.generate(1, 1);
        for (a, b) in train.samples.iter().zip(&test.samples) {
            assert_ne!(a.x.data(), b.x.data(), "train/test leakage");
        }
    }

    #[test]
    fn values_in_range_and_nontrivial() {
        let gen = SyntheticCifar::default();
        let d = gen.generate(3, 0);
        for s in &d.samples {
            assert!(s.x.data().iter().all(|v| (-1.0..=1.0).contains(v)));
            let spread = s.x.data().iter().cloned().fold(f32::MIN, f32::max)
                - s.x.data().iter().cloned().fold(f32::MAX, f32::min);
            assert!(spread > 0.5, "degenerate image (spread {spread})");
        }
    }

    #[test]
    fn class_indices_partition() {
        let gen = SyntheticCifar::default();
        let d = gen.generate(4, 0);
        let total: usize = (0..10).map(|c| d.class_indices(c).len()).sum();
        assert_eq!(total, d.len());
        for c in 0..10 {
            assert_eq!(d.class_indices(c).len(), 4);
            for &i in d.class_indices(c) {
                assert_eq!(d.samples[i].label, c);
            }
        }
    }

    #[test]
    fn task_subset_filters() {
        let gen = SyntheticCifar::default();
        let d = gen.generate(2, 0);
        let t = d.task_subset(&[0, 1]);
        assert_eq!(t.len(), 4);
        assert!(t.iter().all(|s| s.label < 2));
    }

    #[test]
    fn classes_are_separable_by_simple_statistic() {
        // Per-class channel means should differ between at least some
        // class pairs — a sanity floor for learnability.
        let gen = SyntheticCifar::default();
        let d = gen.generate(8, 0);
        let mean_of = |c: usize| -> f32 {
            let idx = d.class_indices(c);
            idx.iter()
                .map(|&i| {
                    let s = &d.samples[i];
                    s.x.data().iter().sum::<f32>() / s.x.data().len() as f32
                })
                .sum::<f32>()
                / idx.len() as f32
        };
        let m0 = mean_of(0);
        let m9 = mean_of(9);
        assert!((m0 - m9).abs() > 0.05, "classes statistically identical");
    }

    #[test]
    fn learnable_by_tiny_model() {
        // A small f32 model should fit a handful of samples from 2 classes
        // well above chance within a few epochs.
        use crate::nn::{Model, ModelConfig};
        let gen = SyntheticCifar { image_size: 16, ..Default::default() };
        let d = gen.generate(10, 0);
        let task: Vec<&Sample> = d.task_subset(&[0, 1]);
        let cfg = ModelConfig {
            in_channels: 3,
            image_size: 16,
            conv_channels: 4,
            num_classes: 10,
            grad_clip: 1.0,
        };
        let mut m = Model::new(cfg, 11);
        for _ in 0..6 {
            for s in &task {
                m.train_step(&s.x, s.label, 2, 0.05);
            }
        }
        let acc = task
            .iter()
            .filter(|s| m.predict(&s.x, 2) == s.label)
            .count() as f32
            / task.len() as f32;
        assert!(acc >= 0.8, "train accuracy {acc} < 0.8 on 2-class subset");
    }
}
