//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them once on the CPU PJRT client, and
//! execute them from the L3 hot path. Python never runs here.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax ≥
//! 0.5 serialized protos carry 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and python/compile/aot.py).
//!
//! [`XlaModel`] wraps the two entry points with the paper's model
//! signature and implements a full train loop host-side: parameters stay
//! in [`xla::Literal`]s between steps (one host copy per step — the
//! model is ~340 KB, negligible on the CPU client; see EXPERIMENTS.md
//! §Perf for the measured per-step overhead).

use crate::nn::ModelConfig;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Names of the artifact files for one model geometry.
#[derive(Clone, Debug)]
pub struct ArtifactSet {
    pub forward: PathBuf,
    pub train_step: PathBuf,
}

impl ArtifactSet {
    /// The paper-geometry artifacts in `dir` (`forward.hlo.txt`, …).
    pub fn paper(dir: impl AsRef<Path>) -> ArtifactSet {
        let d = dir.as_ref();
        ArtifactSet { forward: d.join("forward.hlo.txt"), train_step: d.join("train_step.hlo.txt") }
    }

    /// The tiny-geometry artifacts (fast tests).
    pub fn tiny(dir: impl AsRef<Path>) -> ArtifactSet {
        let d = dir.as_ref();
        ArtifactSet {
            forward: d.join("forward_tiny.hlo.txt"),
            train_step: d.join("train_step_tiny.hlo.txt"),
        }
    }

    pub fn exist(&self) -> bool {
        self.forward.exists() && self.train_step.exists()
    }
}

/// A PJRT client that compiles artifact files into executables.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// CPU PJRT client (the only plugin in this environment).
    pub fn cpu() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(XlaRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn compile_artifact(&self, path: impl AsRef<Path>) -> Result<xla::PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| anyhow!("compile {}: {e}", path.display()))
    }

    /// Load + compile a full artifact set into an [`XlaModel`].
    pub fn load_model(&self, set: &ArtifactSet, config: ModelConfig) -> Result<XlaModel> {
        if !set.exist() {
            bail!(
                "artifacts missing ({} / {}) — run `make artifacts`",
                set.forward.display(),
                set.train_step.display()
            );
        }
        Ok(XlaModel {
            forward: self.compile_artifact(&set.forward)?,
            train_step: self.compile_artifact(&set.train_step)?,
            params: None,
            config,
        })
    }
}

/// Convert a CHW/OIHW/2-D tensor into an f32 literal of the same shape.
pub fn literal_from_tensor(t: &Tensor<f32>) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().dims().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data()).reshape(&dims).map_err(|e| anyhow!("reshape literal: {e}"))
}

/// Extract an f32 vector from a literal.
pub fn literal_to_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))
}

/// The paper's model, AOT-compiled, with parameters held as literals.
pub struct XlaModel {
    forward: xla::PjRtLoadedExecutable,
    train_step: xla::PjRtLoadedExecutable,
    /// (k1, k2, w); `None` until [`Self::set_params`].
    params: Option<[xla::Literal; 3]>,
    pub config: ModelConfig,
}

impl XlaModel {
    /// Install parameters from host tensors.
    pub fn set_params(&mut self, p: &crate::nn::Params) -> Result<()> {
        self.params = Some([
            literal_from_tensor(&p.k1)?,
            literal_from_tensor(&p.k2)?,
            literal_from_tensor(&p.w)?,
        ]);
        Ok(())
    }

    fn params(&self) -> Result<&[xla::Literal; 3]> {
        self.params.as_ref().context("XlaModel params not set — call set_params first")
    }

    /// Read parameters back to host tensors (checkpoint/verification).
    pub fn read_params(&self) -> Result<crate::nn::Params> {
        let [k1, k2, w] = self.params()?;
        let c = &self.config;
        let sh4 = |o: usize, i: usize| crate::tensor::Shape::d4(o, i, 3, 3);
        Ok(crate::nn::Params {
            k1: Tensor::from_vec(sh4(c.conv_channels, c.in_channels), literal_to_vec(k1)?),
            k2: Tensor::from_vec(sh4(c.conv_channels, c.conv_channels), literal_to_vec(k2)?),
            w: Tensor::from_vec(
                crate::tensor::Shape::d2(c.dense_in(), c.num_classes),
                literal_to_vec(w)?,
            ),
        })
    }

    /// Inference: logits over all classes.
    pub fn infer(&self, x: &Tensor<f32>) -> Result<Vec<f32>> {
        let [k1, k2, w] = self.params()?;
        let xl = literal_from_tensor(x)?;
        let result = self
            .forward
            .execute::<&xla::Literal>(&[k1, k2, w, &xl])
            .map_err(|e| anyhow!("forward execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("forward readback: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("forward tuple: {e}"))?;
        literal_to_vec(&out)
    }

    /// One batch-1 SGD step; updates the held parameters, returns
    /// (loss, logits).
    pub fn train_step(
        &mut self,
        x: &Tensor<f32>,
        label: usize,
        active_classes: usize,
        lr: f32,
    ) -> Result<(f32, Vec<f32>)> {
        let c = self.config.num_classes;
        assert!(label < active_classes && active_classes <= c);
        let mut onehot = vec![0f32; c];
        onehot[label] = 1.0;
        let mask: Vec<f32> =
            (0..c).map(|i| if i < active_classes { 1.0 } else { 0.0 }).collect();

        let [k1, k2, w] = self.params()?;
        let xl = literal_from_tensor(x)?;
        let oh = xla::Literal::vec1(&onehot);
        let mk = xla::Literal::vec1(&mask);
        let lrl = xla::Literal::scalar(lr);

        let result = self
            .train_step
            .execute::<&xla::Literal>(&[k1, k2, w, &xl, &oh, &mk, &lrl])
            .map_err(|e| anyhow!("train_step execute: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("train_step readback: {e}"))?;
        let mut elems = tuple.to_tuple().map_err(|e| anyhow!("train_step tuple: {e}"))?;
        if elems.len() != 5 {
            bail!("train_step returned {}-tuple, expected 5", elems.len());
        }
        let logits = literal_to_vec(&elems[4])?;
        let loss = literal_scalar(&elems[3])?;
        let w_new = elems.remove(2);
        let k2_new = elems.remove(1);
        let k1_new = elems.remove(0);
        self.params = Some([k1_new, k2_new, w_new]);
        Ok((loss, logits))
    }
}

/// Extract a scalar f32 from a rank-0 literal.
fn literal_scalar(l: &xla::Literal) -> Result<f32> {
    let v = l.to_vec::<f32>().map_err(|e| anyhow!("scalar literal: {e}"))?;
    v.first().copied().context("empty scalar literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts + a PJRT client live in
    // rust/tests/xla_runtime.rs (integration); here only pure host-side
    // helpers are covered so `cargo test --lib` stays artifact-free.

    #[test]
    fn artifact_set_paths() {
        let s = ArtifactSet::paper("artifacts");
        assert!(s.forward.ends_with("forward.hlo.txt"));
        let t = ArtifactSet::tiny("artifacts");
        assert!(t.train_step.ends_with("train_step_tiny.hlo.txt"));
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::from_vec(
            crate::tensor::Shape::d3(2, 2, 2),
            (0..8).map(|i| i as f32).collect(),
        );
        let l = literal_from_tensor(&t).unwrap();
        assert_eq!(literal_to_vec(&l).unwrap(), t.data());
    }

    #[test]
    fn missing_artifacts_detected() {
        let s = ArtifactSet::paper("/nonexistent");
        assert!(!s.exist());
    }
}
