//! # tinycl — reproduction of "TinyCL: An Efficient Hardware Architecture
//! # for Continual Learning on Autonomous Systems" (Ressa et al., 2024)
//!
//! Three-layer stack (see DESIGN.md):
//! * **L3 (this crate)** — cycle-accurate simulator of the TinyCL
//!   microarchitecture (`sim`), 65 nm cost model (`hw`), continual-learning
//!   policies (`cl`), dataset substrate (`data`), f32 and Q4.12 functional
//!   models (`nn`, `qnn`), PJRT runtime for the AOT software baseline
//!   (`runtime`), the training coordinator (`coordinator`) and the
//!   replicated dynamic-batching inference server (`serve`: replica
//!   pool, priority lanes, open-loop load generation).
//! * **L2/L1 (python/, build-time only)** — JAX model + Pallas kernels,
//!   AOT-lowered to HLO text artifacts loaded by `runtime`.

pub mod cl;
pub mod coordinator;
pub mod data;
pub mod fixed;
pub mod hw;
pub mod nn;
pub mod obs;
pub mod qnn;
/// PJRT runtime for the AOT software baseline — needs the off-by-default
/// `xla` cargo feature (default builds run on machines with no PJRT
/// plugin; see rust/README.md).
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tensor;
pub mod util;
