//! ReLU activation (forward + backward mask).

use crate::tensor::Tensor;

pub fn forward(x: &Tensor<f32>) -> Tensor<f32> {
    x.map(|v| v.max(0.0))
}

/// dL/dx = dL/dy where the *pre-activation* was positive, else 0.
pub fn backward(dy: &Tensor<f32>, pre_activation: &Tensor<f32>) -> Tensor<f32> {
    assert_eq!(dy.shape(), pre_activation.shape());
    dy.zip_with(pre_activation, |g, x| if x > 0.0 { g } else { 0.0 })
}

pub fn forward_vec(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| v.max(0.0)).collect()
}

pub fn backward_vec(dy: &[f32], pre_activation: &[f32]) -> Vec<f32> {
    assert_eq!(dy.len(), pre_activation.len());
    dy.iter()
        .zip(pre_activation)
        .map(|(&g, &x)| if x > 0.0 { g } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;

    #[test]
    fn forward_clamps_negatives() {
        let x = Tensor::from_vec(Shape::d1(4), vec![-2.0, -0.0, 1.0, 3.5]);
        assert_eq!(forward(&x).data(), &[0.0, 0.0, 1.0, 3.5]);
    }

    #[test]
    fn backward_masks_by_preactivation() {
        let pre = Tensor::from_vec(Shape::d1(4), vec![-1.0, 0.0, 2.0, 5.0]);
        let dy = Tensor::from_vec(Shape::d1(4), vec![10.0, 10.0, 10.0, 10.0]);
        assert_eq!(backward(&dy, &pre).data(), &[0.0, 0.0, 10.0, 10.0]);
    }

    #[test]
    fn vec_variants_agree() {
        let pre = vec![-1.0, 2.0];
        let dy = vec![3.0, 4.0];
        assert_eq!(forward_vec(&pre), vec![0.0, 2.0]);
        assert_eq!(backward_vec(&dy, &pre), vec![0.0, 4.0]);
    }
}
