//! The paper's evaluation model (§IV-A): Conv3×3 + ReLU + Conv3×3 + ReLU
//! + Dense, trained with SGD. The paper trains at batch size 1; PR 2
//! adds true minibatch entry points ([`Model::forward_batch`] /
//! [`Model::train_batch`], mean-gradient semantics) that the GEMM
//! engine executes as batched packed GEMMs, optionally sharded across
//! scoped worker threads. Batch-1 [`Model::train_step`] delegates to
//! the batched path with `B = 1` (numerically identical).

use super::{conv, dense, gemm, loss, relu, sgd};
use crate::tensor::{Shape, Tensor};
use crate::util::rng::Pcg32;
use std::cell::RefCell;

/// Which compute core executes the conv/dense layers. Both engines share
/// parameters and init; they differ only in float summation order (the
/// GEMM core is pinned to the naive one within 1e-4 by
/// `tests/gemm_vs_naive.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Engine {
    /// Per-element reference loops (`nn::conv`, `nn::dense`).
    #[default]
    Naive,
    /// im2col + cache-blocked GEMM (`nn::gemm`) — the `f32-fast` backend.
    Gemm,
}

/// Highest supported replay cut point. Cut 0 replays raw inputs through
/// the full network (the classic policies' regime); cut 1 stores the
/// post-ReLU conv1 activation and trains conv2 + dense; cut 2 stores the
/// post-ReLU conv2 activation and trains the dense head only.
pub const MAX_CUT: usize = 2;

/// Model geometry. Defaults mirror §IV-A: 32×32×3 input, 8 filters per
/// conv (stride 1, pad 1 — geometry-preserving), 10 classes.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub in_channels: usize,
    pub image_size: usize,
    pub conv_channels: usize,
    pub num_classes: usize,
    /// Gradient-norm clip for the float path (`f32::INFINITY` = off).
    pub grad_clip: f32,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            in_channels: 3,
            image_size: 32,
            conv_channels: 8,
            num_classes: 10,
            grad_clip: f32::INFINITY,
        }
    }
}

impl ModelConfig {
    pub fn dense_in(&self) -> usize {
        self.conv_channels * self.image_size * self.image_size
    }

    /// Gradient-normalization shift for the fixed-point conv kernel
    /// gradient: ≈log₂(H·W), the length of the spatial reduction. The
    /// barrel shift at the multiplier output keeps the 32-bit Q8.24
    /// accumulator from wrapping (`qnn`/`sim` only; the float path uses
    /// true gradients + norm clipping). See `Fx::mul_acc_shifted`.
    pub fn kgrad_shift(&self) -> u32 {
        (self.image_size * self.image_size).next_power_of_two().trailing_zeros()
    }

    /// Gradient-normalization shift for the fixed-point fused dense
    /// weight update: ≈½·log₂(fan-in). Unlike the conv kernel gradient
    /// this product never wraps (no reduction), but its magnitude —
    /// activation (≤ 8) × loss gradient — is orders above the useful
    /// weight scale (~√(1/fan-in)), and at batch 1 the un-normalized
    /// update drives W into saturation over a long GDumb epoch
    /// (EXPERIMENTS.md E5). The same product-bus barrel shift fixes it.
    pub fn dense_grad_shift(&self) -> u32 {
        self.dense_in().next_power_of_two().trailing_zeros() / 2
    }

    /// Activation shape at a replay cut (both convs are geometry-
    /// preserving, so only the channel count depends on the cut).
    pub fn cut_shape(&self, cut: usize) -> Shape {
        assert!(cut <= MAX_CUT, "cut {cut} out of range (max {MAX_CUT})");
        match cut {
            0 => Shape::d3(self.in_channels, self.image_size, self.image_size),
            _ => Shape::d3(self.conv_channels, self.image_size, self.image_size),
        }
    }

    /// Stored bytes per raw sample at 16 bit per value — the unit of the
    /// paper's replay-memory accounting (6.144 MB = 1000 × 32·32·3 × 2 B).
    pub fn sample_bytes(&self) -> u64 {
        self.cut_bytes(0)
    }

    /// Stored bytes per replayed item at `cut` (Q4.12 → 2 B per value).
    pub fn cut_bytes(&self, cut: usize) -> u64 {
        self.cut_shape(cut).numel() as u64 * 2
    }

    /// Total trainable parameters.
    pub fn param_count(&self) -> usize {
        self.conv_channels * self.in_channels * 9
            + self.conv_channels * self.conv_channels * 9
            + self.dense_in() * self.num_classes
    }
}

/// Trainable parameters.
#[derive(Clone, Debug)]
pub struct Params {
    pub k1: Tensor<f32>, // (C, in, 3, 3)
    pub k2: Tensor<f32>, // (C, C, 3, 3)
    pub w: Tensor<f32>,  // (C*H*W, classes)
}

/// Per-parameter gradients from one backward pass.
#[derive(Clone, Debug)]
pub struct Gradients {
    pub k1: Tensor<f32>,
    pub k2: Tensor<f32>,
    pub w: Tensor<f32>,
}

/// Intermediate activations needed by the backward pass (the paper's
/// "Partial Feature memory" holds exactly these).
pub struct ForwardCache {
    pub x: Tensor<f32>,
    pub z1: Tensor<f32>, // conv1 pre-activation
    pub a1: Tensor<f32>, // relu(z1)
    pub z2: Tensor<f32>, // conv2 pre-activation
    pub a2: Tensor<f32>, // relu(z2), flattened into dense
    pub logits: Vec<f32>,
}

/// Result of a single train step.
#[derive(Clone, Debug)]
pub struct TrainOutput {
    pub loss: f32,
    pub correct: bool,
}

/// Result of one minibatch train step.
#[derive(Clone, Debug)]
pub struct BatchTrainOutput {
    /// Mean cross-entropy loss over the batch.
    pub loss: f32,
    /// Top-1 correct predictions over the batch (pre-update logits).
    pub correct: usize,
}

/// Caches from one batched GEMM-engine forward pass. Activations are in
/// the channel-major packed layout (`nn::gemm`); the im2col column
/// matrices are kept so backward never re-packs the same input.
struct GemmBatchCache {
    cols1: Vec<f32>,
    z1: Vec<f32>,
    cols2: Vec<f32>,
    z2: Vec<f32>,
    /// Sample-major post-ReLU dense input (B × dense_in).
    xd: Vec<f32>,
    /// Sample-major logits (B × num_classes).
    logits: Vec<f32>,
}

/// Conv kernels repacked into microkernel tile order (`gemm::PackedA`)
/// — built once per weight snapshot ([`Model::pack_weights`], called at
/// `Learner::clone_replica` / barrier re-broadcast), consumed by the
/// serve-path forward, and dropped by every weight update.
#[derive(Clone)]
struct PackedWeights {
    k1: gemm::PackedA,
    k2: gemm::PackedA,
}

impl PackedWeights {
    fn pack(params: &Params) -> PackedWeights {
        let d1 = params.k1.shape().dims();
        let d2 = params.k2.shape().dims();
        PackedWeights {
            k1: gemm::PackedA::pack(d1[0], d1[1] * d1[2] * d1[3], params.k1.data()),
            k2: gemm::PackedA::pack(d2[0], d2[1] * d2[2] * d2[3], params.k2.data()),
        }
    }

    fn is_fresh(&self, params: &Params) -> bool {
        let d1 = params.k1.shape().dims();
        let d2 = params.k2.shape().dims();
        self.k1.matches(d1[0], d1[1] * d1[2] * d1[3], params.k1.data())
            && self.k2.matches(d2[0], d2[1] * d2[2] * d2[3], params.k2.data())
    }
}

/// Pool of reusable f32 scratch buffers for the GEMM engine's im2col
/// column matrices and conv outputs — allocation churn at serve batch
/// sizes is measurable, and every consumer clears + resizes before use
/// so recycling never changes results.
#[derive(Clone, Default)]
struct Scratch {
    bufs: Vec<Vec<f32>>,
}

impl Scratch {
    fn take(&mut self) -> Vec<f32> {
        match self.bufs.pop() {
            Some(buf) => {
                scratch_obs().0.inc();
                buf
            }
            None => {
                scratch_obs().1.inc();
                Vec::new()
            }
        }
    }

    fn put(&mut self, mut buf: Vec<f32>) {
        buf.clear();
        self.bufs.push(buf);
    }
}

/// `(reuse, alloc)` counters for the GEMM scratch pool — a reuse rate
/// near 1 after warm-up is the pool doing its job.
pub(crate) fn scratch_obs() -> (&'static crate::obs::Counter, &'static crate::obs::Counter) {
    static CELLS: std::sync::OnceLock<(
        &'static crate::obs::Counter,
        &'static crate::obs::Counter,
    )> = std::sync::OnceLock::new();
    *CELLS.get_or_init(|| {
        (
            crate::obs::counter("scratch_total{result=\"reuse\"}"),
            crate::obs::counter("scratch_total{result=\"alloc\"}"),
        )
    })
}

/// `(hit, miss)` counters for the snapshot-packed conv-weight cache: a
/// serving replica should hit on every forward after `pack_weights`; a
/// miss means the batch paid an O(m·k) repack because a weight update
/// invalidated the snapshot.
pub(crate) fn pack_obs() -> (&'static crate::obs::Counter, &'static crate::obs::Counter) {
    static CELLS: std::sync::OnceLock<(
        &'static crate::obs::Counter,
        &'static crate::obs::Counter,
    )> = std::sync::OnceLock::new();
    *CELLS.get_or_init(|| {
        (
            crate::obs::counter("pack_cache_total{result=\"hit\"}"),
            crate::obs::counter("pack_cache_total{result=\"miss\"}"),
        )
    })
}

// Clone: the serving tests snapshot a warmed model (one copy moves onto
// the server's model thread, the other stays behind as the per-sample
// parity oracle).
#[derive(Clone)]
pub struct Model {
    pub config: ModelConfig,
    pub params: Params,
    /// Compute core for conv/dense (default: naive reference loops).
    pub engine: Engine,
    /// Scoped worker threads the GEMM engine may use (1 = serial).
    /// Thread count never changes results: the sharded GEMMs are
    /// bit-identical to single-thread (see `nn::gemm`).
    pub threads: usize,
    /// Snapshot-packed conv kernels for the serve-path forward. `None`
    /// until [`Model::pack_weights`]; invalidated by every weight
    /// update (train step, suffix step, `reinit`, `reinit_suffix`).
    packed: Option<PackedWeights>,
    /// Recycled GEMM scratch buffers (interior-mutable so the `&self`
    /// forward paths can reuse them across calls).
    scratch: RefCell<Scratch>,
    /// Monotone weight-snapshot version, bumped by every weight update
    /// (the serving layer's diff re-broadcast key). Survives `reinit`.
    version: u64,
    /// Per-tensor stamp (k1, k2, w): the `version` at each tensor's
    /// last update. Diff sync copies exactly the tensors whose stamp
    /// differs from the source snapshot's.
    tensor_versions: [u64; 3],
    /// Per-task dense heads (always ≥ 1). The *active* head's live
    /// tensor is `params.w`; `heads[active_task]` is a stale
    /// placeholder parked there by the last head swap. Heads may be
    /// narrower than `config.num_classes` (a task classifies only its
    /// own class slice), which is what keeps per-task growth small.
    heads: Vec<Tensor<f32>>,
    /// Version stamp of each *parked* head (`head_versions[active_task]`
    /// is stale; the active head's stamp lives in `tensor_versions[2]`).
    head_versions: Vec<u64>,
    /// Which head `params.w` currently is.
    active_task: usize,
    /// When set, training moves only the active dense head — the conv
    /// backbone is shared across tasks and stays frozen, so a train
    /// barrier's diff re-broadcast ships one head, not the model.
    freeze_backbone: bool,
}

impl Model {
    /// Fresh model with He-uniform init, deterministic in `seed`.
    pub fn new(config: ModelConfig, seed: u64) -> Model {
        let mut rng = Pcg32::new(seed, 100);
        let params = Params {
            k1: super::init::conv_kernel(
                &mut rng,
                config.conv_channels,
                config.in_channels,
                3,
                3,
            ),
            k2: super::init::conv_kernel(
                &mut rng,
                config.conv_channels,
                config.conv_channels,
                3,
                3,
            ),
            w: super::init::dense_weights(&mut rng, config.dense_in(), config.num_classes),
        };
        let heads = vec![params.w.clone()];
        Model {
            config,
            params,
            engine: Engine::Naive,
            threads: 1,
            packed: None,
            scratch: RefCell::new(Scratch::default()),
            version: 0,
            tensor_versions: [0; 3],
            heads,
            head_versions: vec![0],
            active_task: 0,
            freeze_backbone: false,
        }
    }

    pub fn from_params(config: ModelConfig, params: Params) -> Model {
        assert_eq!(
            params.w.shape(),
            &Shape::d2(config.dense_in(), config.num_classes)
        );
        let heads = vec![params.w.clone()];
        Model {
            config,
            params,
            engine: Engine::Naive,
            threads: 1,
            packed: None,
            scratch: RefCell::new(Scratch::default()),
            version: 0,
            tensor_versions: [0; 3],
            heads,
            head_versions: vec![0],
            active_task: 0,
            freeze_backbone: false,
        }
    }

    /// Record a weight update: drop the packed conv snapshot (it must
    /// never survive an update) and advance the version stamps of the
    /// tensors that moved. Every update site funnels through here so
    /// pack invalidation and diff-sync bookkeeping cannot drift apart.
    fn touch(&mut self, k1: bool, k2: bool, w: bool) {
        self.packed = None;
        self.version += 1;
        let v = self.version;
        if k1 {
            self.tensor_versions[0] = v;
        }
        if k2 {
            self.tensor_versions[1] = v;
        }
        if w {
            self.tensor_versions[2] = v;
        }
    }

    /// Current weight-snapshot version (advances on every update,
    /// including `reinit`).
    pub fn weights_version(&self) -> u64 {
        self.version
    }

    /// Bytes of one full weight snapshot (the re-broadcast baseline
    /// diff sync saves against): the shared conv backbone plus every
    /// task head. For a single-head model this is exactly the pre-PR-10
    /// value.
    pub fn weights_bytes(&self) -> u64 {
        let head_values: usize = (0..self.heads.len()).map(|h| self.head_view(h).data().len()).sum();
        4 * (self.params.k1.data().len() + self.params.k2.data().len() + head_values) as u64
    }

    // ---- Multi-task heads -------------------------------------------
    //
    // One shared conv backbone (k1, k2), K dense heads. The active
    // head's live tensor is always `params.w`, so every existing
    // forward/train path works unchanged on whatever head is active;
    // `set_active_task` swaps heads in O(1) without moving weight
    // bytes. Heads carry their own version stamps so the serve layer's
    // diff re-broadcast ships exactly the heads that moved.

    /// Number of task heads (≥ 1; a fresh model has one).
    pub fn num_tasks(&self) -> usize {
        self.heads.len()
    }

    /// The task whose head is live in `params.w`.
    pub fn active_task(&self) -> usize {
        self.active_task
    }

    /// Output width of the *active* head, derived from the dense weight
    /// shape (heads added via [`Model::add_task_head`] may be narrower
    /// than `config.num_classes`).
    pub fn out_classes(&self) -> usize {
        self.params.w.shape().dims()[1]
    }

    /// Freeze (or thaw) the conv backbone: frozen, `train_batch` routes
    /// through the deepest-cut suffix step and moves only the active
    /// dense head.
    pub fn set_freeze_backbone(&mut self, freeze: bool) {
        self.freeze_backbone = freeze;
    }

    /// Whether the conv backbone is frozen.
    pub fn backbone_frozen(&self) -> bool {
        self.freeze_backbone
    }

    /// Add a fresh dense head with `classes` outputs, deterministic in
    /// `seed`, and return its task id. Zero growth in the shared
    /// backbone: the new parameters are exactly one `dense_in × classes`
    /// tensor ([`Model::head_bytes`]). The active task is unchanged.
    pub fn add_task_head(&mut self, classes: usize, seed: u64) -> usize {
        let w = fresh_head(&self.config, classes, seed);
        // A new head is a weight update like any other: it gets its own
        // fresh stamp so replica diff sync ships it (and nothing else).
        self.version += 1;
        self.head_versions.push(self.version);
        self.heads.push(w);
        self.heads.len() - 1
    }

    /// Make task `task`'s head the live `params.w`. O(1): the outgoing
    /// head parks back into its slot (with its current stamp), the
    /// incoming head swaps in. No weight bytes move, the version does
    /// not advance, and the conv weight pack survives (it holds only
    /// k1/k2). Returns an actionable error when the head does not exist
    /// — callers must `add_task_head` first.
    pub fn set_active_task(&mut self, task: usize) -> Result<(), String> {
        if task >= self.heads.len() {
            return Err(format!(
                "task {task} has no head: model has {} head(s) (ids 0..={}); \
                 call add_task_head before routing task {task}",
                self.heads.len(),
                self.heads.len() - 1
            ));
        }
        if task == self.active_task {
            return Ok(());
        }
        let old = self.active_task;
        std::mem::swap(&mut self.heads[old], &mut self.params.w);
        self.head_versions[old] = self.tensor_versions[2];
        std::mem::swap(&mut self.heads[task], &mut self.params.w);
        self.tensor_versions[2] = self.head_versions[task];
        self.active_task = task;
        Ok(())
    }

    /// Current weights of head `task` — the live `params.w` when active,
    /// the parked copy otherwise.
    pub fn head_view(&self, task: usize) -> &Tensor<f32> {
        assert!(
            task < self.heads.len(),
            "task {task} has no head: model has {} head(s)",
            self.heads.len()
        );
        if task == self.active_task {
            &self.params.w
        } else {
            &self.heads[task]
        }
    }

    /// Version stamp of head `task`'s current weights.
    fn head_stamp(&self, task: usize) -> u64 {
        if task == self.active_task {
            self.tensor_versions[2]
        } else {
            self.head_versions[task]
        }
    }

    /// Bytes of head `task` — the entire per-task parameter growth
    /// (compare [`Model::weights_bytes`] for the whole model).
    pub fn head_bytes(&self, task: usize) -> u64 {
        4 * self.head_view(task).data().len() as u64
    }

    /// Adopt `src`'s weights by diff: copy exactly the tensors whose
    /// version stamp differs, adopt `src`'s stamps, and return the bytes
    /// copied. Both models must share snapshot lineage (replicas of one
    /// pool, synced at every barrier) — stamps, not contents, decide.
    /// A dense-only update (deepest-cut train step) copies just `w` and
    /// keeps this model's conv weight pack valid: `PackedWeights` holds
    /// only k1/k2, so the pack survives untouched unless a conv tensor
    /// moved, in which case `src`'s (freshly packed) snapshot pack is
    /// adopted too.
    pub fn sync_weights_from(&mut self, src: &Model) -> u64 {
        let mut bytes = 0u64;
        // Heads added on the source since this replica's snapshot.
        while self.heads.len() < src.heads.len() {
            let h = self.heads.len();
            self.heads.push(src.head_view(h).clone());
            self.head_versions.push(src.head_stamp(h));
            bytes += 4 * self.heads[h].data().len() as u64;
        }
        // Align the active head (a local swap — no weight bytes move);
        // after this, `params.w` on both sides is the same head, so the
        // tensor loop below diffs it by stamp like any other tensor.
        if self.active_task != src.active_task {
            self.set_active_task(src.active_task).expect("heads grown above");
        }
        // A source with *fewer* heads (a `reinit` resets to one) wins:
        // replicas mirror the snapshot, they never out-live it.
        if self.heads.len() > src.heads.len() {
            self.heads.truncate(src.heads.len());
            self.head_versions.truncate(src.heads.len());
        }
        // Parked heads whose stamp advanced on the source.
        for h in 0..self.heads.len() {
            if h == self.active_task || self.head_versions[h] == src.head_stamp(h) {
                continue;
            }
            self.heads[h] = src.head_view(h).clone();
            self.head_versions[h] = src.head_stamp(h);
            bytes += 4 * self.heads[h].data().len() as u64;
        }
        let mut conv_changed = false;
        for i in 0..3 {
            if self.tensor_versions[i] == src.tensor_versions[i] {
                continue;
            }
            let (dst_t, src_t) = match i {
                0 => (&mut self.params.k1, &src.params.k1),
                1 => (&mut self.params.k2, &src.params.k2),
                _ => (&mut self.params.w, &src.params.w),
            };
            *dst_t = src_t.clone();
            bytes += 4 * dst_t.data().len() as u64;
            self.tensor_versions[i] = src.tensor_versions[i];
            conv_changed |= i < 2;
        }
        self.version = src.version;
        if conv_changed {
            self.packed = src.packed.clone();
        }
        bytes
    }

    /// Select the compute core (builder-style; parameters are untouched).
    pub fn with_engine(mut self, engine: Engine) -> Model {
        self.engine = engine;
        self
    }

    /// Set the GEMM worker-thread budget (builder-style; clamped to ≥1).
    pub fn with_threads(mut self, threads: usize) -> Model {
        self.threads = threads.max(1);
        self
    }

    /// Re-initialize parameters in place (GDumb's "dumb learner" trains
    /// from scratch for every query), deterministic in `seed`,
    /// preserving the engine and thread configuration. Centralizes the
    /// engine-preserving reset the CL layer and the coordinator both
    /// hand-rolled before PR 2 (flagged in PR 1 review). Resets the
    /// multi-task state too: a reinit model matches `Model::new` — one
    /// head, task 0 active, backbone thawed.
    pub fn reinit(&mut self, seed: u64) {
        let (engine, threads, version) = (self.engine, self.threads, self.version);
        *self = Model::new(self.config.clone(), seed).with_engine(engine).with_threads(threads);
        // A reinit is a weight update like any other: the version keeps
        // advancing (never resets) so replica diff sync stays sound.
        self.version = version;
        self.touch(true, true, true);
    }

    /// Repack the conv kernels into microkernel tile order for the
    /// serve-path forward. Called once per weight snapshot — replica
    /// creation and barrier re-broadcast go through
    /// `Learner::clone_replica`, which packs the clone — so steady-state
    /// serving never repacks per batch. Every weight update drops the
    /// pack; a debug assertion on the serve path catches any update
    /// site that forgets.
    pub fn pack_weights(&mut self) {
        self.packed = Some(PackedWeights::pack(&self.params));
    }

    // Engine dispatch: one seam per layer computation, so the forward
    // and backward passes read identically for both cores.

    fn conv_forward(&self, x: &Tensor<f32>, k: &Tensor<f32>) -> Tensor<f32> {
        match self.engine {
            Engine::Naive => conv::forward(x, k, 1, 1),
            Engine::Gemm => gemm::forward(x, k, 1, 1),
        }
    }

    fn conv_input_grad(&self, dy: &Tensor<f32>, k: &Tensor<f32>, x_shape: &Shape) -> Tensor<f32> {
        match self.engine {
            Engine::Naive => conv::input_grad(dy, k, x_shape, 1, 1),
            Engine::Gemm => gemm::input_grad(dy, k, x_shape, 1, 1),
        }
    }

    fn conv_kernel_grad(&self, dy: &Tensor<f32>, x: &Tensor<f32>, k_shape: &Shape) -> Tensor<f32> {
        match self.engine {
            Engine::Naive => conv::kernel_grad(dy, x, k_shape, 1, 1),
            Engine::Gemm => gemm::kernel_grad(dy, x, k_shape, 1, 1),
        }
    }

    fn dense_forward(&self, flat: &[f32]) -> Vec<f32> {
        self.dense_forward_with(flat, &self.params.w)
    }

    fn dense_forward_with(&self, flat: &[f32], w: &Tensor<f32>) -> Vec<f32> {
        match self.engine {
            Engine::Naive => dense::forward(flat, w),
            Engine::Gemm => gemm::dense_forward(flat, w),
        }
    }

    fn dense_input_grad(&self, dlogits: &[f32]) -> Vec<f32> {
        match self.engine {
            Engine::Naive => dense::input_grad(dlogits, &self.params.w),
            Engine::Gemm => gemm::dense_input_grad(dlogits, &self.params.w),
        }
    }

    fn dense_weight_grad(&self, dlogits: &[f32], flat: &[f32]) -> Tensor<f32> {
        match self.engine {
            Engine::Naive => dense::weight_grad(dlogits, flat),
            Engine::Gemm => gemm::dense_weight_grad(dlogits, flat),
        }
    }

    /// Forward pass keeping the caches backward needs.
    pub fn forward_cached(&self, x: &Tensor<f32>) -> ForwardCache {
        let z1 = self.conv_forward(x, &self.params.k1);
        let a1 = relu::forward(&z1);
        let z2 = self.conv_forward(&a1, &self.params.k2);
        let a2 = relu::forward(&z2);
        let logits = self.dense_forward(a2.data());
        ForwardCache { x: x.clone(), z1, a1, z2, a2, logits }
    }

    /// Inference only: logits.
    pub fn forward(&self, x: &Tensor<f32>) -> Vec<f32> {
        self.forward_cached(x).logits
    }

    /// Predicted class over the first `active_classes` logits.
    pub fn predict(&self, x: &Tensor<f32>, active_classes: usize) -> usize {
        loss::predict(&self.forward(x), active_classes)
    }

    /// Full backward pass from the CE gradient. Returns gradients for all
    /// parameters (does not mutate the model).
    pub fn backward(&self, cache: &ForwardCache, dlogits: &[f32]) -> Gradients {
        // Dense layer.
        let dw = self.dense_weight_grad(dlogits, cache.a2.data());
        let da2_flat = self.dense_input_grad(dlogits);
        let da2 = Tensor::from_vec(cache.a2.shape().clone(), da2_flat);

        // ReLU 2 + conv2.
        let dz2 = relu::backward(&da2, &cache.z2);
        let dk2 = self.conv_kernel_grad(&dz2, &cache.a1, self.params.k2.shape());
        let da1 = self.conv_input_grad(&dz2, &self.params.k2, cache.a1.shape());

        // ReLU 1 + conv1 (no input gradient needed at the first layer).
        let dz1 = relu::backward(&da1, &cache.z1);
        let dk1 = self.conv_kernel_grad(&dz1, &cache.x, self.params.k1.shape());

        Gradients { k1: dk1, k2: dk2, w: dw }
    }

    /// One SGD train step (batch 1) on `(x, label)` with the head masked to
    /// `active_classes`. Returns loss and top-1 correctness. Delegates to
    /// [`Model::train_batch`] with `B = 1` (identical numerics).
    pub fn train_step(
        &mut self,
        x: &Tensor<f32>,
        label: usize,
        active_classes: usize,
        lr: f32,
    ) -> TrainOutput {
        let out = self.train_batch(&[x], &[label], active_classes, lr);
        TrainOutput { loss: out.loss, correct: out.correct == 1 }
    }

    /// Batched inference: per-sample logits. The GEMM engine runs the
    /// serve-path forward — snapshot-packed weights, fused conv+ReLU
    /// epilogues, recycled scratch — which is bit-identical to the
    /// train-path forward (`nn::gemm` module docs prove each step); the
    /// naive engine loops.
    pub fn forward_batch(&self, xs: &[&Tensor<f32>]) -> Vec<Vec<f32>> {
        assert!(!xs.is_empty(), "empty batch");
        match self.engine {
            Engine::Naive => xs.iter().map(|x| self.forward(x)).collect(),
            Engine::Gemm => {
                let classes = self.out_classes();
                let logits = self.gemm_serve_logits(xs);
                logits.chunks(classes).map(|c| c.to_vec()).collect()
            }
        }
    }

    /// Batched inference over a *mixed-task* batch: one shared backbone
    /// pass for the whole batch (the zero-growth payoff — cross-task
    /// requests still coalesce into one conv pass), then each sample's
    /// logits come from its own task head. `tasks[i]` must name an
    /// existing head. Per sample this matches the single-task forward
    /// bit-for-bit on the naive engine and within float round-off on
    /// the GEMM engine (the shared pass reuses the cut-point datapath,
    /// whose summation order differs from the fused serve forward).
    pub fn forward_batch_tasks(&self, xs: &[&Tensor<f32>], tasks: &[usize]) -> Vec<Vec<f32>> {
        assert!(!xs.is_empty(), "empty batch");
        assert_eq!(xs.len(), tasks.len(), "batch inputs vs tasks");
        let acts = self.forward_to_cut_batch(xs, MAX_CUT);
        acts.iter()
            .zip(tasks)
            .map(|(a, &t)| self.dense_forward_with(a.data(), self.head_view(t)))
            .collect()
    }

    /// Predicted classes for a mixed-task batch, each sample masked to
    /// the first `actives[i]` outputs of its own head.
    pub fn predict_batch_tasks(
        &self,
        xs: &[&Tensor<f32>],
        tasks: &[usize],
        actives: &[usize],
    ) -> Vec<usize> {
        assert_eq!(xs.len(), actives.len(), "batch inputs vs active masks");
        self.forward_batch_tasks(xs, tasks)
            .iter()
            .zip(actives)
            .map(|(logits, &active)| loss::predict(logits, active))
            .collect()
    }

    /// Serve-path batched forward: inference needs no pre-activations,
    /// so both convs run with the ReLU fused into the microkernel's
    /// C-tile store, the kernels come from the packed snapshot (packed
    /// on the fly when no snapshot exists — e.g. a model queried
    /// mid-training), and the column/activation buffers are recycled
    /// across calls. Returns sample-major logits (B × classes).
    fn gemm_serve_logits(&self, xs: &[&Tensor<f32>]) -> Vec<f32> {
        let b = xs.len();
        let hw = self.config.image_size;
        let n = hw * hw;
        let cin = self.config.in_channels;
        let cc = self.config.conv_channels;
        let t = self.threads;
        assert_eq!(
            xs[0].shape(),
            &Shape::d3(cin, hw, hw),
            "input must match the model geometry"
        );
        let packed_store;
        let pw: &PackedWeights = match &self.packed {
            Some(p) => {
                debug_assert!(
                    p.is_fresh(&self.params),
                    "stale packed weights: a weight update failed to invalidate the pack"
                );
                pack_obs().0.inc();
                p
            }
            None => {
                pack_obs().1.inc();
                packed_store = PackedWeights::pack(&self.params);
                &packed_store
            }
        };
        let packed_input;
        let x0: &[f32] = if b == 1 {
            xs[0].data()
        } else {
            packed_input = gemm::pack_batch(xs);
            &packed_input
        };
        let mut cols1 = self.scratch.borrow_mut().take();
        gemm::im2col_batch_into(x0, b, cin, hw, hw, 3, 3, 1, 1, t, &mut cols1);
        let mut a1 = self.scratch.borrow_mut().take();
        gemm::conv_forward_batch_packed_into(&cols1, &pw.k1, b * n, true, &mut a1, t);
        let mut cols2 = self.scratch.borrow_mut().take();
        gemm::im2col_batch_into(&a1, b, cc, hw, hw, 3, 3, 1, 1, t, &mut cols2);
        let mut a2 = self.scratch.borrow_mut().take();
        gemm::conv_forward_batch_packed_into(&cols2, &pw.k2, b * n, true, &mut a2, t);
        let logits = if b == 1 {
            gemm::dense_forward_batch(&a2, &self.params.w, b, t)
        } else {
            let xd = gemm::packed_to_rows(&a2, cc, b, n);
            gemm::dense_forward_batch(&xd, &self.params.w, b, t)
        };
        let mut sc = self.scratch.borrow_mut();
        sc.put(cols1);
        sc.put(a1);
        sc.put(cols2);
        sc.put(a2);
        logits
    }

    /// One SGD step on a minibatch with mean-gradient semantics: the
    /// per-sample gradients are averaged, clipped once and applied once
    /// (for `B = 1` this reduces exactly to the paper's per-sample
    /// step). Both engines implement the same semantics, so batched
    /// naive-vs-GEMM parity holds at any batch size
    /// (`tests/batched_parity.rs`).
    pub fn train_batch(
        &mut self,
        xs: &[&Tensor<f32>],
        labels: &[usize],
        active_classes: usize,
        lr: f32,
    ) -> BatchTrainOutput {
        assert!(!xs.is_empty(), "empty batch");
        assert_eq!(xs.len(), labels.len(), "batch inputs vs labels");
        if self.freeze_backbone {
            // Frozen backbone: run the conv prefix forward-only and
            // train just the active dense head via the deepest-cut
            // suffix step — a barrier diff re-broadcast then ships one
            // head instead of the whole model.
            let acts = self.forward_to_cut_batch(xs, MAX_CUT);
            let act_refs: Vec<&Tensor<f32>> = acts.iter().collect();
            return self.train_batch_from(MAX_CUT, &act_refs, labels, active_classes, lr);
        }
        let (mut grads, loss_sum, correct) = match self.engine {
            Engine::Naive => self.naive_batch_grads(xs, labels, active_classes),
            Engine::Gemm => self.gemm_batch_grads(xs, labels, active_classes),
        };
        let scale = 1.0 / xs.len() as f32;
        scale_tensor(&mut grads.k1, scale);
        scale_tensor(&mut grads.k2, scale);
        scale_tensor(&mut grads.w, scale);
        sgd::clip_by_norm(&mut grads.k1, self.config.grad_clip);
        sgd::clip_by_norm(&mut grads.k2, self.config.grad_clip);
        sgd::clip_by_norm(&mut grads.w, self.config.grad_clip);
        self.apply(&grads, lr);
        BatchTrainOutput { loss: loss_sum / xs.len() as f32, correct }
    }

    /// Naive-engine minibatch: loop the per-sample reference backward
    /// and sum the gradients (the parity oracle for the GEMM path).
    fn naive_batch_grads(
        &self,
        xs: &[&Tensor<f32>],
        labels: &[usize],
        active_classes: usize,
    ) -> (Gradients, f32, usize) {
        let mut acc: Option<Gradients> = None;
        let mut loss_sum = 0.0f32;
        let mut correct = 0usize;
        for (x, &label) in xs.iter().zip(labels) {
            let cache = self.forward_cached(x);
            let (l, dl) = loss::softmax_ce(&cache.logits, label, active_classes);
            loss_sum += l;
            correct += usize::from(loss::predict(&cache.logits, active_classes) == label);
            let g = self.backward(&cache, &dl);
            acc = Some(match acc {
                None => g,
                Some(mut sum) => {
                    add_tensor(&mut sum.k1, &g.k1);
                    add_tensor(&mut sum.k2, &g.k2);
                    add_tensor(&mut sum.w, &g.w);
                    sum
                }
            });
        }
        (acc.expect("non-empty batch"), loss_sum, correct)
    }

    /// GEMM-engine batched forward: pack once, one GEMM per layer pass.
    fn gemm_forward_batch(&self, xs: &[&Tensor<f32>]) -> GemmBatchCache {
        let b = xs.len();
        let hw = self.config.image_size;
        let n = hw * hw;
        let cin = self.config.in_channels;
        let cc = self.config.conv_channels;
        let t = self.threads;
        assert_eq!(
            xs[0].shape(),
            &Shape::d3(cin, hw, hw),
            "input must match the model geometry"
        );
        // For B = 1 the packed layout *is* CHW — borrow instead of copy.
        let packed_input;
        let x0: &[f32] = if b == 1 {
            xs[0].data()
        } else {
            packed_input = gemm::pack_batch(xs);
            &packed_input
        };
        let mut cols1 = self.scratch.borrow_mut().take();
        let (oh, ow) = gemm::im2col_batch_into(x0, b, cin, hw, hw, 3, 3, 1, 1, t, &mut cols1);
        debug_assert_eq!((oh, ow), (hw, hw), "3×3 s1 p1 conv preserves geometry");
        let z1 = gemm::conv_forward_batch(&cols1, &self.params.k1, b * n, t);
        let a1 = relu::forward_vec(&z1);
        let mut cols2 = self.scratch.borrow_mut().take();
        gemm::im2col_batch_into(&a1, b, cc, hw, hw, 3, 3, 1, 1, t, &mut cols2);
        let z2 = gemm::conv_forward_batch(&cols2, &self.params.k2, b * n, t);
        let a2 = relu::forward_vec(&z2);
        let xd = if b == 1 { a2 } else { gemm::packed_to_rows(&a2, cc, b, n) };
        let logits = gemm::dense_forward_batch(&xd, &self.params.w, b, t);
        GemmBatchCache { cols1, z1, cols2, z2, xd, logits }
    }

    /// GEMM-engine minibatch: each backward pass is one large GEMM over
    /// the packed batch, reusing the forward's im2col column matrices.
    fn gemm_batch_grads(
        &self,
        xs: &[&Tensor<f32>],
        labels: &[usize],
        active_classes: usize,
    ) -> (Gradients, f32, usize) {
        let b = xs.len();
        let hw = self.config.image_size;
        let n = hw * hw;
        let cc = self.config.conv_channels;
        let classes = self.out_classes();
        let t = self.threads;
        let fwd = self.gemm_forward_batch(xs);
        let (dlogits, loss_sum, correct) =
            batch_loss_grads(&fwd.logits, labels, classes, active_classes);
        // Dense layer.
        let d_in = self.config.dense_in();
        let dw = gemm::dense_weight_grad_batch(&dlogits, &fwd.xd, b, d_in, classes, t);
        let da2_rows = gemm::dense_input_grad_batch(&dlogits, &self.params.w, b, t);
        let da2 = if b == 1 { da2_rows } else { gemm::rows_to_packed(&da2_rows, cc, b, n) };
        // ReLU 2 + conv2 (cols2 reused — no second im2col of a1).
        let dz2 = relu::backward_vec(&da2, &fwd.z2);
        let dk2 = gemm::conv_kernel_grad_batch(&dz2, &fwd.cols2, self.params.k2.shape(), b * n, t);
        let da1 = gemm::conv_input_grad_batch(&dz2, &self.params.k2, b, hw, hw, 1, 1, hw, hw, t);
        // ReLU 1 + conv1 (no input gradient needed at the first layer).
        let dz1 = relu::backward_vec(&da1, &fwd.z1);
        let dk1 = gemm::conv_kernel_grad_batch(&dz1, &fwd.cols1, self.params.k1.shape(), b * n, t);
        // Recycle the column matrices — the next step's im2col refills
        // them without reallocating.
        let GemmBatchCache { cols1, cols2, .. } = fwd;
        let mut sc = self.scratch.borrow_mut();
        sc.put(cols1);
        sc.put(cols2);
        (Gradients { k1: dk1, k2: dk2, w: dw }, loss_sum, correct)
    }

    // ---- Cut-point datapath (latent replay) -------------------------
    //
    // The network splits at a replay cut into a frozen prefix and a
    // trainable suffix. The prefix runs forward-only (batched, at
    // admission time); the suffix trains from stored activations with
    // the same mean-gradient minibatch semantics as `train_batch`. At
    // cut 0 both entry points delegate to the full-network paths, so
    // cut-0 latent replay is bit-identical to raw replay by
    // construction (pinned in the tests below).

    /// Forward the frozen prefix to `cut` for a whole batch. The GEMM
    /// engine runs one packed GEMM set over the batch; the naive engine
    /// loops the reference convs. Cut 0 returns the inputs unchanged.
    pub fn forward_to_cut_batch(&self, xs: &[&Tensor<f32>], cut: usize) -> Vec<Tensor<f32>> {
        assert!(cut <= MAX_CUT, "cut {cut} out of range (max {MAX_CUT})");
        assert!(!xs.is_empty(), "empty batch");
        if cut == 0 {
            return xs.iter().map(|x| (*x).clone()).collect();
        }
        match self.engine {
            Engine::Naive => xs
                .iter()
                .map(|x| {
                    let a1 = relu::forward(&self.conv_forward(x, &self.params.k1));
                    if cut == 1 {
                        a1
                    } else {
                        relu::forward(&self.conv_forward(&a1, &self.params.k2))
                    }
                })
                .collect(),
            Engine::Gemm => {
                let b = xs.len();
                let hw = self.config.image_size;
                let n = hw * hw;
                let cin = self.config.in_channels;
                let cc = self.config.conv_channels;
                let t = self.threads;
                let packed_input;
                let x0: &[f32] = if b == 1 {
                    xs[0].data()
                } else {
                    packed_input = gemm::pack_batch(xs);
                    &packed_input
                };
                let (cols1, _, _) = gemm::im2col_batch(x0, b, cin, hw, hw, 3, 3, 1, 1, t);
                let mut a =
                    relu::forward_vec(&gemm::conv_forward_batch(&cols1, &self.params.k1, b * n, t));
                if cut == 2 {
                    let (cols2, _, _) = gemm::im2col_batch(&a, b, cc, hw, hw, 3, 3, 1, 1, t);
                    a = relu::forward_vec(&gemm::conv_forward_batch(
                        &cols2,
                        &self.params.k2,
                        b * n,
                        t,
                    ));
                }
                let rows = if b == 1 { a } else { gemm::packed_to_rows(&a, cc, b, n) };
                rows.chunks(cc * n)
                    .map(|r| Tensor::from_vec(Shape::d3(cc, hw, hw), r.to_vec()))
                    .collect()
            }
        }
    }

    /// One mean-gradient SGD minibatch on the suffix from `cut`, fed
    /// stored activations. Only the suffix parameters move; at cut 0
    /// this *is* [`Model::train_batch`].
    pub fn train_batch_from(
        &mut self,
        cut: usize,
        acts: &[&Tensor<f32>],
        labels: &[usize],
        active_classes: usize,
        lr: f32,
    ) -> BatchTrainOutput {
        assert!(cut <= MAX_CUT, "cut {cut} out of range (max {MAX_CUT})");
        if cut == 0 {
            return self.train_batch(acts, labels, active_classes, lr);
        }
        assert!(!acts.is_empty(), "empty batch");
        assert_eq!(acts.len(), labels.len(), "batch inputs vs labels");
        for a in acts {
            assert_eq!(a.shape(), &self.config.cut_shape(cut), "activation vs cut geometry");
        }
        let b = acts.len();
        let (dk2, mut dw, loss_sum, correct) = if cut == 1 {
            let (dk2, dw, l, c) = self.suffix_grads_from_a1(acts, labels, active_classes);
            (Some(dk2), dw, l, c)
        } else {
            let (dw, l, c) = self.dense_grads_from_a2(acts, labels, active_classes);
            (None, dw, l, c)
        };
        let scale = 1.0 / b as f32;
        // Suffix steps update weights too: cut 1 moves k2 + w, cut 2
        // moves only the dense head (the cheap-diff re-broadcast case).
        self.touch(false, cut == 1, true);
        if let Some(mut dk2) = dk2 {
            scale_tensor(&mut dk2, scale);
            sgd::clip_by_norm(&mut dk2, self.config.grad_clip);
            sgd::step(&mut self.params.k2, &dk2, lr);
        }
        scale_tensor(&mut dw, scale);
        sgd::clip_by_norm(&mut dw, self.config.grad_clip);
        sgd::step(&mut self.params.w, &dw, lr);
        BatchTrainOutput { loss: loss_sum / b as f32, correct }
    }

    /// Cut-1 suffix gradients (conv2 + dense) from stored a1 activations.
    /// Shares every layer op with the full path, so the suffix step's
    /// k2/w updates are bit-identical to `train_batch`'s on both engines.
    fn suffix_grads_from_a1(
        &self,
        acts: &[&Tensor<f32>],
        labels: &[usize],
        active_classes: usize,
    ) -> (Tensor<f32>, Tensor<f32>, f32, usize) {
        match self.engine {
            Engine::Naive => {
                let mut sum: Option<(Tensor<f32>, Tensor<f32>)> = None;
                let mut loss_sum = 0.0f32;
                let mut correct = 0usize;
                for (a1, &label) in acts.iter().zip(labels) {
                    let z2 = self.conv_forward(a1, &self.params.k2);
                    let a2 = relu::forward(&z2);
                    let logits = self.dense_forward(a2.data());
                    let (l, dl) = loss::softmax_ce(&logits, label, active_classes);
                    loss_sum += l;
                    correct += usize::from(loss::predict(&logits, active_classes) == label);
                    let dw = self.dense_weight_grad(&dl, a2.data());
                    let da2 = Tensor::from_vec(a2.shape().clone(), self.dense_input_grad(&dl));
                    let dz2 = relu::backward(&da2, &z2);
                    let dk2 = self.conv_kernel_grad(&dz2, a1, self.params.k2.shape());
                    sum = Some(match sum {
                        None => (dk2, dw),
                        Some((mut sk2, mut sw)) => {
                            add_tensor(&mut sk2, &dk2);
                            add_tensor(&mut sw, &dw);
                            (sk2, sw)
                        }
                    });
                }
                let (dk2, dw) = sum.expect("non-empty batch");
                (dk2, dw, loss_sum, correct)
            }
            Engine::Gemm => {
                let b = acts.len();
                let hw = self.config.image_size;
                let n = hw * hw;
                let cc = self.config.conv_channels;
                let classes = self.out_classes();
                let d_in = self.config.dense_in();
                let t = self.threads;
                let packed_acts;
                let a1: &[f32] = if b == 1 {
                    acts[0].data()
                } else {
                    packed_acts = gemm::pack_batch(acts);
                    &packed_acts
                };
                let (cols2, _, _) = gemm::im2col_batch(a1, b, cc, hw, hw, 3, 3, 1, 1, t);
                let z2 = gemm::conv_forward_batch(&cols2, &self.params.k2, b * n, t);
                let a2 = relu::forward_vec(&z2);
                let xd = if b == 1 { a2 } else { gemm::packed_to_rows(&a2, cc, b, n) };
                let logits = gemm::dense_forward_batch(&xd, &self.params.w, b, t);
                let (dlogits, loss_sum, correct) =
                    batch_loss_grads(&logits, labels, classes, active_classes);
                let dw = gemm::dense_weight_grad_batch(&dlogits, &xd, b, d_in, classes, t);
                let da2_rows = gemm::dense_input_grad_batch(&dlogits, &self.params.w, b, t);
                let da2 = if b == 1 { da2_rows } else { gemm::rows_to_packed(&da2_rows, cc, b, n) };
                let dz2 = relu::backward_vec(&da2, &z2);
                let dk2 =
                    gemm::conv_kernel_grad_batch(&dz2, &cols2, self.params.k2.shape(), b * n, t);
                (dk2, dw, loss_sum, correct)
            }
        }
    }

    /// Cut-2 gradients (dense head only) from stored a2 activations.
    fn dense_grads_from_a2(
        &self,
        acts: &[&Tensor<f32>],
        labels: &[usize],
        active_classes: usize,
    ) -> (Tensor<f32>, f32, usize) {
        match self.engine {
            Engine::Naive => {
                let mut sum: Option<Tensor<f32>> = None;
                let mut loss_sum = 0.0f32;
                let mut correct = 0usize;
                for (a2, &label) in acts.iter().zip(labels) {
                    let logits = self.dense_forward(a2.data());
                    let (l, dl) = loss::softmax_ce(&logits, label, active_classes);
                    loss_sum += l;
                    correct += usize::from(loss::predict(&logits, active_classes) == label);
                    let dw = self.dense_weight_grad(&dl, a2.data());
                    sum = Some(match sum {
                        None => dw,
                        Some(mut s) => {
                            add_tensor(&mut s, &dw);
                            s
                        }
                    });
                }
                (sum.expect("non-empty batch"), loss_sum, correct)
            }
            Engine::Gemm => {
                let b = acts.len();
                let classes = self.out_classes();
                let d_in = self.config.dense_in();
                let t = self.threads;
                let xd = gemm::rows_from_samples(acts);
                let logits = gemm::dense_forward_batch(&xd, &self.params.w, b, t);
                let (dlogits, loss_sum, correct) =
                    batch_loss_grads(&logits, labels, classes, active_classes);
                let dw = gemm::dense_weight_grad_batch(&dlogits, &xd, b, d_in, classes, t);
                (dw, loss_sum, correct)
            }
        }
    }

    /// Re-initialize only the parameters at and after `cut` (latent
    /// replay's "dumb" suffix learner), deterministic in `seed` and
    /// leaving the frozen prefix untouched. `reinit_suffix(0, s)` is
    /// bit-identical to [`Model::reinit`]`(s)`: the fresh draw fills
    /// k1, k2, w from one rng stream in that order, so copying a prefix
    /// of the tensors never perturbs the rest.
    pub fn reinit_suffix(&mut self, cut: usize, seed: u64) {
        assert!(cut <= MAX_CUT, "cut {cut} out of range (max {MAX_CUT})");
        self.touch(cut == 0, cut <= 1, true);
        let fresh = Model::new(self.config.clone(), seed);
        if cut == 0 {
            self.params.k1 = fresh.params.k1;
        }
        if cut <= 1 {
            self.params.k2 = fresh.params.k2;
        }
        self.params.w = fresh.params.w;
    }

    /// Apply pre-computed gradients. Drops the packed weight snapshot:
    /// the pack must never survive a weight update.
    pub fn apply(&mut self, grads: &Gradients, lr: f32) {
        self.touch(true, true, true);
        sgd::step(&mut self.params.k1, &grads.k1, lr);
        sgd::step(&mut self.params.k2, &grads.k2, lr);
        sgd::step(&mut self.params.w, &grads.w, lr);
    }
}

/// Deterministic fresh dense-head draw: the same He-uniform init the
/// constructor uses, on its own rng stream so head draws never collide
/// with `Model::new`'s. The quantized model quantizes this exact draw
/// (`QModel::add_task_head`), keeping the two engines' heads
/// comparable sample-for-sample.
pub fn fresh_head(config: &ModelConfig, classes: usize, seed: u64) -> Tensor<f32> {
    assert!(classes >= 1, "a head needs at least one output class");
    let mut rng = Pcg32::new(seed, 200);
    super::init::dense_weights(&mut rng, config.dense_in(), classes)
}

fn add_tensor(dst: &mut Tensor<f32>, src: &Tensor<f32>) {
    for (d, &s) in dst.data_mut().iter_mut().zip(src.data()) {
        *d += s;
    }
}

fn scale_tensor(t: &mut Tensor<f32>, k: f32) {
    for v in t.data_mut() {
        *v *= k;
    }
}

/// Per-row softmax-CE losses and gradients over sample-major logits.
fn batch_loss_grads(
    logits: &[f32],
    labels: &[usize],
    classes: usize,
    active_classes: usize,
) -> (Vec<f32>, f32, usize) {
    let mut dlogits = vec![0.0f32; labels.len() * classes];
    let mut loss_sum = 0.0f32;
    let mut correct = 0usize;
    for (bi, &label) in labels.iter().enumerate() {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let (l, dl) = loss::softmax_ce(row, label, active_classes);
        loss_sum += l;
        correct += usize::from(loss::predict(row, active_classes) == label);
        dlogits[bi * classes..(bi + 1) * classes].copy_from_slice(&dl);
    }
    (dlogits, loss_sum, correct)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> ModelConfig {
        ModelConfig {
            in_channels: 3,
            image_size: 8,
            conv_channels: 4,
            num_classes: 4,
            grad_clip: f32::INFINITY,
        }
    }

    fn rand_image(seed: u64, cfg: &ModelConfig) -> Tensor<f32> {
        let mut rng = Pcg32::seeded(seed);
        let shape = Shape::d3(cfg.in_channels, cfg.image_size, cfg.image_size);
        let n = shape.numel();
        Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    #[test]
    fn shapes_consistent() {
        let cfg = ModelConfig::default();
        let m = Model::new(cfg.clone(), 1);
        assert_eq!(m.params.k1.shape().dims(), &[8, 3, 3, 3]);
        assert_eq!(m.params.k2.shape().dims(), &[8, 8, 3, 3]);
        assert_eq!(m.params.w.shape().dims(), &[8192, 10]);
        assert_eq!(cfg.param_count(), 8 * 3 * 9 + 8 * 8 * 9 + 8192 * 10);
    }

    #[test]
    fn train_step_reduces_loss_on_same_sample() {
        let cfg = tiny_config();
        let mut m = Model::new(cfg.clone(), 2);
        let x = rand_image(3, &cfg);
        let first = m.train_step(&x, 1, 4, 0.05).loss;
        let mut last = first;
        for _ in 0..20 {
            last = m.train_step(&x, 1, 4, 0.05).loss;
        }
        assert!(
            last < first * 0.5,
            "loss did not drop: first={first} last={last}"
        );
    }

    #[test]
    fn masked_classes_never_predicted() {
        let cfg = tiny_config();
        let m = Model::new(cfg.clone(), 4);
        let x = rand_image(5, &cfg);
        for _ in 0..5 {
            assert!(m.predict(&x, 2) < 2);
        }
    }

    #[test]
    fn deterministic_training() {
        let cfg = tiny_config();
        let x = rand_image(7, &cfg);
        let mut a = Model::new(cfg.clone(), 9);
        let mut b = Model::new(cfg.clone(), 9);
        for _ in 0..3 {
            let la = a.train_step(&x, 0, 4, 0.1).loss;
            let lb = b.train_step(&x, 0, 4, 0.1).loss;
            assert_eq!(la, lb);
        }
        assert_eq!(a.params.w.data(), b.params.w.data());
    }

    #[test]
    fn engines_share_init_and_agree_on_loss() {
        let cfg = tiny_config();
        let mut naive = Model::new(cfg.clone(), 2);
        let mut fast = Model::new(cfg.clone(), 2).with_engine(Engine::Gemm);
        assert_eq!(naive.params.w.data(), fast.params.w.data(), "init must not depend on engine");
        let x = rand_image(3, &cfg);
        for step in 0..5 {
            let ln = naive.train_step(&x, 1, 4, 0.05).loss;
            let lf = fast.train_step(&x, 1, 4, 0.05).loss;
            assert!(
                (ln - lf).abs() <= 1e-4 * (1.0 + ln.abs()),
                "step {step}: naive loss {ln} vs gemm loss {lf}"
            );
        }
        for (a, b) in naive.params.k1.data().iter().zip(fast.params.k1.data()) {
            assert!((a - b).abs() <= 1e-4, "k1 diverged: {a} vs {b}");
        }
    }

    #[test]
    fn reinit_is_deterministic_and_preserves_engine() {
        let cfg = tiny_config();
        let mut m = Model::new(cfg.clone(), 5).with_engine(Engine::Gemm).with_threads(3);
        let x = rand_image(6, &cfg);
        m.train_step(&x, 1, 4, 0.05);
        m.reinit(5);
        let fresh = Model::new(cfg, 5);
        assert_eq!(m.params.w.data(), fresh.params.w.data(), "reinit must match a fresh init");
        assert_eq!(m.engine, Engine::Gemm, "reinit dropped the engine");
        assert_eq!(m.threads, 3, "reinit dropped the thread budget");
    }

    #[test]
    fn train_batch_is_mean_of_fixed_param_grads() {
        // Reference: per-sample backward at FIXED params, summed, scaled
        // by 1/B, applied once — what minibatch SGD means. The naive
        // engine must match it exactly (same code path by construction);
        // the GEMM engine within float round-off.
        let cfg = tiny_config();
        let xs: Vec<Tensor<f32>> = (0..3).map(|i| rand_image(20 + i, &cfg)).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        let labels = [0usize, 1, 2];
        let lr = 0.05;
        for engine in [Engine::Naive, Engine::Gemm] {
            let mut m = Model::new(cfg.clone(), 8).with_engine(engine);
            let mut r = Model::new(cfg.clone(), 8); // naive reference copy
            let mut sums: Option<Gradients> = None;
            for (x, &label) in refs.iter().zip(&labels) {
                let cache = r.forward_cached(x);
                let (_, dl) = super::loss::softmax_ce(&cache.logits, label, 4);
                let g = r.backward(&cache, &dl);
                sums = Some(match sums {
                    None => g,
                    Some(mut s) => {
                        add_tensor(&mut s.k1, &g.k1);
                        add_tensor(&mut s.k2, &g.k2);
                        add_tensor(&mut s.w, &g.w);
                        s
                    }
                });
            }
            let mut g = sums.unwrap();
            scale_tensor(&mut g.k1, 1.0 / 3.0);
            scale_tensor(&mut g.k2, 1.0 / 3.0);
            scale_tensor(&mut g.w, 1.0 / 3.0);
            r.apply(&g, lr);

            m.train_batch(&refs, &labels, 4, lr);
            let tol = if engine == Engine::Naive { 0.0 } else { 1e-4 };
            crate::util::proptest::assert_close(
                m.params.w.data(),
                r.params.w.data(),
                tol,
                &format!("{engine:?} minibatch w"),
            );
            crate::util::proptest::assert_close(
                m.params.k1.data(),
                r.params.k1.data(),
                tol,
                &format!("{engine:?} minibatch k1"),
            );
        }
    }

    #[test]
    fn forward_batch_matches_per_sample_forward() {
        let cfg = tiny_config();
        let xs: Vec<Tensor<f32>> = (0..4).map(|i| rand_image(40 + i, &cfg)).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        for engine in [Engine::Naive, Engine::Gemm] {
            let m = Model::new(cfg.clone(), 9).with_engine(engine).with_threads(2);
            let batched = m.forward_batch(&refs);
            assert_eq!(batched.len(), 4);
            for (bi, x) in xs.iter().enumerate() {
                crate::util::proptest::assert_close(
                    &batched[bi],
                    &m.forward(x),
                    1e-5,
                    &format!("{engine:?} logits sample {bi}"),
                );
            }
        }
    }

    #[test]
    fn packed_serve_forward_bit_identical_and_invalidated_on_update() {
        let cfg = tiny_config();
        let xs: Vec<Tensor<f32>> = (0..3).map(|i| rand_image(100 + i, &cfg)).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        let labels = [0usize, 1, 2];
        let mut m = Model::new(cfg.clone(), 51).with_engine(Engine::Gemm).with_threads(2);
        m.train_batch(&refs, &labels, 4, 0.05);
        let before = m.forward_batch(&refs);
        m.pack_weights();
        assert!(m.packed.is_some());
        assert_eq!(m.forward_batch(&refs), before, "packed serve forward must be bit-identical");
        // Every weight-update site must drop the pack (the serve path
        // debug-asserts freshness, so a missed site also fails there).
        m.train_batch(&refs, &labels, 4, 0.05);
        assert!(m.packed.is_none(), "train step kept a stale pack");
        m.pack_weights();
        let acts = m.forward_to_cut_batch(&refs, 2);
        let act_refs: Vec<&Tensor<f32>> = acts.iter().collect();
        m.train_batch_from(2, &act_refs, &labels, 4, 0.05);
        assert!(m.packed.is_none(), "suffix step kept a stale pack");
        m.pack_weights();
        m.reinit_suffix(2, 7);
        assert!(m.packed.is_none(), "reinit_suffix kept a stale pack");
        m.pack_weights();
        m.reinit(7);
        assert!(m.packed.is_none(), "reinit kept a stale pack");
        // The on-the-fly fallback still agrees with per-sample forward.
        let post = m.forward_batch(&refs);
        for (bi, x) in xs.iter().enumerate() {
            crate::util::proptest::assert_close(
                &post[bi],
                &m.forward(x),
                1e-5,
                &format!("sample {bi}"),
            );
        }
    }

    #[test]
    fn backward_does_not_mutate() {
        let cfg = tiny_config();
        let m = Model::new(cfg.clone(), 11);
        let x = rand_image(13, &cfg);
        let before = m.params.w.data().to_vec();
        let cache = m.forward_cached(&x);
        let (_, dl) = super::loss::softmax_ce(&cache.logits, 0, 4);
        let _ = m.backward(&cache, &dl);
        assert_eq!(m.params.w.data(), &before[..]);
    }

    #[test]
    fn cut_geometry_accounting() {
        let cfg = ModelConfig::default();
        // Paper memory unit: one raw 32×32×3 sample at 16 bit = 6144 B.
        assert_eq!(cfg.sample_bytes(), 6144);
        assert_eq!(cfg.cut_shape(0).numel(), 3 * 32 * 32);
        // Post-conv activations: 8 channels, geometry preserved.
        assert_eq!(cfg.cut_bytes(1), 8 * 32 * 32 * 2);
        assert_eq!(cfg.cut_bytes(2), 8 * 32 * 32 * 2);
    }

    #[test]
    fn forward_to_cut_matches_full_forward_prefix() {
        let cfg = tiny_config();
        let xs: Vec<Tensor<f32>> = (0..3).map(|i| rand_image(60 + i, &cfg)).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        for engine in [Engine::Naive, Engine::Gemm] {
            let m = Model::new(cfg.clone(), 14).with_engine(engine).with_threads(2);
            // Cut 0 is the identity.
            let c0 = m.forward_to_cut_batch(&refs, 0);
            assert_eq!(c0[1].data(), xs[1].data());
            // Cuts 1 and 2 must match the per-sample cached forward.
            let c1 = m.forward_to_cut_batch(&refs, 1);
            let c2 = m.forward_to_cut_batch(&refs, 2);
            let oracle = Model::new(cfg.clone(), 14); // naive reference
            for (bi, x) in xs.iter().enumerate() {
                let cache = oracle.forward_cached(x);
                assert_eq!(c1[bi].shape(), &cfg.cut_shape(1));
                crate::util::proptest::assert_close(
                    c1[bi].data(),
                    cache.a1.data(),
                    1e-5,
                    &format!("{engine:?} cut-1 sample {bi}"),
                );
                crate::util::proptest::assert_close(
                    c2[bi].data(),
                    cache.a2.data(),
                    1e-5,
                    &format!("{engine:?} cut-2 sample {bi}"),
                );
            }
        }
    }

    #[test]
    fn train_batch_from_cut0_is_train_batch() {
        let cfg = tiny_config();
        let xs: Vec<Tensor<f32>> = (0..3).map(|i| rand_image(70 + i, &cfg)).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        let labels = [0usize, 1, 2];
        for engine in [Engine::Naive, Engine::Gemm] {
            let mut a = Model::new(cfg.clone(), 21).with_engine(engine);
            let mut b = Model::new(cfg.clone(), 21).with_engine(engine);
            let oa = a.train_batch(&refs, &labels, 4, 0.05);
            let ob = b.train_batch_from(0, &refs, &labels, 4, 0.05);
            assert_eq!(oa.loss, ob.loss, "{engine:?} cut-0 loss");
            assert_eq!(a.params.k1.data(), b.params.k1.data(), "{engine:?} cut-0 k1");
            assert_eq!(a.params.k2.data(), b.params.k2.data(), "{engine:?} cut-0 k2");
            assert_eq!(a.params.w.data(), b.params.w.data(), "{engine:?} cut-0 w");
        }
    }

    #[test]
    fn suffix_step_matches_full_step_and_freezes_prefix() {
        // Train one model through the full network and another through
        // the cut-1 suffix fed the same a1 activations: the k2/w updates
        // must agree bit-for-bit (identical layer ops on identical
        // inputs) while the suffix model's k1 stays frozen.
        let cfg = tiny_config();
        let xs: Vec<Tensor<f32>> = (0..3).map(|i| rand_image(80 + i, &cfg)).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        let labels = [1usize, 3, 0];
        for engine in [Engine::Naive, Engine::Gemm] {
            let mut full = Model::new(cfg.clone(), 31).with_engine(engine).with_threads(2);
            let mut suffix = Model::new(cfg.clone(), 31).with_engine(engine).with_threads(2);
            let k1_before = suffix.params.k1.data().to_vec();
            let acts = suffix.forward_to_cut_batch(&refs, 1);
            let act_refs: Vec<&Tensor<f32>> = acts.iter().collect();
            let of = full.train_batch(&refs, &labels, 4, 0.05);
            let os = suffix.train_batch_from(1, &act_refs, &labels, 4, 0.05);
            assert_eq!(of.loss, os.loss, "{engine:?} suffix loss");
            assert_eq!(of.correct, os.correct, "{engine:?} suffix correct");
            assert_eq!(full.params.k2.data(), suffix.params.k2.data(), "{engine:?} k2");
            assert_eq!(full.params.w.data(), suffix.params.w.data(), "{engine:?} w");
            assert_eq!(suffix.params.k1.data(), &k1_before[..], "{engine:?} prefix moved");
            assert_ne!(full.params.k1.data(), &k1_before[..], "{engine:?} full k1 frozen?");
        }
    }

    #[test]
    fn dense_only_cut_freezes_both_convs() {
        let cfg = tiny_config();
        let xs: Vec<Tensor<f32>> = (0..2).map(|i| rand_image(90 + i, &cfg)).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        for engine in [Engine::Naive, Engine::Gemm] {
            let mut m = Model::new(cfg.clone(), 41).with_engine(engine);
            let k1 = m.params.k1.data().to_vec();
            let k2 = m.params.k2.data().to_vec();
            let w = m.params.w.data().to_vec();
            let acts = m.forward_to_cut_batch(&refs, 2);
            let act_refs: Vec<&Tensor<f32>> = acts.iter().collect();
            m.train_batch_from(2, &act_refs, &[0, 1], 4, 0.05);
            assert_eq!(m.params.k1.data(), &k1[..], "{engine:?} k1 moved");
            assert_eq!(m.params.k2.data(), &k2[..], "{engine:?} k2 moved");
            assert_ne!(m.params.w.data(), &w[..], "{engine:?} dense head never trained");
        }
    }

    #[test]
    fn reinit_suffix_cut0_is_full_reinit() {
        let cfg = tiny_config();
        let x = rand_image(17, &cfg);
        let mut a = Model::new(cfg.clone(), 5).with_engine(Engine::Gemm).with_threads(3);
        let mut b = a.clone();
        a.train_step(&x, 1, 4, 0.05);
        b.train_step(&x, 1, 4, 0.05);
        a.reinit(99);
        b.reinit_suffix(0, 99);
        assert_eq!(a.params.k1.data(), b.params.k1.data());
        assert_eq!(a.params.k2.data(), b.params.k2.data());
        assert_eq!(a.params.w.data(), b.params.w.data());
        assert_eq!(b.engine, Engine::Gemm);
        assert_eq!(b.threads, 3);
    }

    #[test]
    fn reinit_suffix_keeps_frozen_prefix() {
        let cfg = tiny_config();
        let mut m = Model::new(cfg.clone(), 5);
        let k1 = m.params.k1.data().to_vec();
        let k2 = m.params.k2.data().to_vec();
        m.reinit_suffix(2, 123);
        assert_eq!(m.params.k1.data(), &k1[..]);
        assert_eq!(m.params.k2.data(), &k2[..]);
        let fresh = Model::new(cfg, 123);
        assert_eq!(m.params.w.data(), fresh.params.w.data(), "w must come from the fresh draw");
    }

    #[test]
    fn head_swap_round_trip_is_bit_exact() {
        let cfg = tiny_config();
        let mut m = Model::new(cfg.clone(), 3);
        let w0 = m.params.w.data().to_vec();
        let t1 = m.add_task_head(2, 77);
        assert_eq!(t1, 1);
        assert_eq!(m.num_tasks(), 2);
        assert_eq!(m.active_task(), 0, "adding a head must not switch tasks");
        m.set_active_task(t1).unwrap();
        assert_eq!(m.out_classes(), 2, "narrow head width comes from the live w shape");
        assert_eq!(m.params.w.data(), fresh_head(&cfg, 2, 77).data());
        m.set_active_task(0).unwrap();
        assert_eq!(m.params.w.data(), &w0[..], "round-trip swap must be bit-exact");
        assert_eq!(m.out_classes(), cfg.num_classes);
    }

    #[test]
    fn set_active_task_missing_head_is_actionable() {
        let mut m = Model::new(tiny_config(), 3);
        let err = m.set_active_task(5).unwrap_err();
        assert!(err.contains("task 5") && err.contains("add_task_head"), "unhelpful: {err}");
        assert_eq!(m.active_task(), 0, "failed switch must not move the active task");
    }

    #[test]
    fn frozen_backbone_moves_only_active_head() {
        let cfg = tiny_config();
        let xs: Vec<Tensor<f32>> = (0..2).map(|i| rand_image(110 + i, &cfg)).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        for engine in [Engine::Naive, Engine::Gemm] {
            let mut m = Model::new(cfg.clone(), 6).with_engine(engine);
            let t1 = m.add_task_head(2, 50);
            let head0 = m.head_view(0).data().to_vec();
            let k1 = m.params.k1.data().to_vec();
            let k2 = m.params.k2.data().to_vec();
            m.set_active_task(t1).unwrap();
            m.set_freeze_backbone(true);
            m.train_batch(&refs, &[0, 1], 2, 0.05);
            assert_eq!(m.params.k1.data(), &k1[..], "{engine:?} frozen k1 moved");
            assert_eq!(m.params.k2.data(), &k2[..], "{engine:?} frozen k2 moved");
            assert_eq!(m.head_view(0).data(), &head0[..], "{engine:?} parked head moved");
            assert_ne!(
                m.head_view(t1).data(),
                fresh_head(&cfg, 2, 50).data(),
                "{engine:?} active head never trained"
            );
        }
    }

    #[test]
    fn mixed_task_router_matches_single_task_forward() {
        let cfg = tiny_config();
        let xs: Vec<Tensor<f32>> = (0..4).map(|i| rand_image(120 + i, &cfg)).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        for engine in [Engine::Naive, Engine::Gemm] {
            let mut m = Model::new(cfg.clone(), 12).with_engine(engine).with_threads(2);
            let t1 = m.add_task_head(2, 33);
            let tasks = [0usize, t1, 0, t1];
            let actives = [4usize, 2, 4, 2];
            let routed = m.forward_batch_tasks(&refs, &tasks);
            let preds = m.predict_batch_tasks(&refs, &tasks, &actives);
            for (bi, (&t, &active)) in tasks.iter().zip(&actives).enumerate() {
                m.set_active_task(t).unwrap();
                let solo = m.forward(&xs[bi]);
                crate::util::proptest::assert_close(
                    &routed[bi],
                    &solo,
                    if engine == Engine::Naive { 0.0 } else { 1e-4 },
                    &format!("{engine:?} routed logits sample {bi}"),
                );
                assert_eq!(preds[bi], loss::predict(&routed[bi], active));
            }
        }
    }

    #[test]
    fn head_diff_sync_ships_one_head() {
        let cfg = tiny_config();
        let mut src = Model::new(cfg.clone(), 9);
        src.add_task_head(2, 40);
        src.add_task_head(2, 41);
        let mut replica = src.clone();
        let x = rand_image(130, &cfg);
        src.set_active_task(1).unwrap();
        src.set_freeze_backbone(true);
        src.train_step(&x, 0, 2, 0.05);
        let bytes = replica.sync_weights_from(&src);
        assert_eq!(bytes, src.head_bytes(1), "only the trained head should ship");
        assert!(bytes * 4 < src.weights_bytes(), "head diff must be ≪ full snapshot");
        assert_eq!(replica.active_task(), 1);
        for h in 0..src.num_tasks() {
            assert_eq!(replica.head_view(h).data(), src.head_view(h).data(), "head {h}");
        }
        assert_eq!(replica.weights_version(), src.weights_version());
    }
}
