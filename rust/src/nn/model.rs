//! The paper's evaluation model (§IV-A): Conv3×3 + ReLU + Conv3×3 + ReLU
//! + Dense, trained with SGD at batch size 1.

use super::{conv, dense, gemm, loss, relu, sgd};
use crate::tensor::{Shape, Tensor};
use crate::util::rng::Pcg32;

/// Which compute core executes the conv/dense layers. Both engines share
/// parameters and init; they differ only in float summation order (the
/// GEMM core is pinned to the naive one within 1e-4 by
/// `tests/gemm_vs_naive.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Engine {
    /// Per-element reference loops (`nn::conv`, `nn::dense`).
    #[default]
    Naive,
    /// im2col + cache-blocked GEMM (`nn::gemm`) — the `f32-fast` backend.
    Gemm,
}

/// Model geometry. Defaults mirror §IV-A: 32×32×3 input, 8 filters per
/// conv (stride 1, pad 1 — geometry-preserving), 10 classes.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub in_channels: usize,
    pub image_size: usize,
    pub conv_channels: usize,
    pub num_classes: usize,
    /// Gradient-norm clip for the float path (`f32::INFINITY` = off).
    pub grad_clip: f32,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            in_channels: 3,
            image_size: 32,
            conv_channels: 8,
            num_classes: 10,
            grad_clip: f32::INFINITY,
        }
    }
}

impl ModelConfig {
    pub fn dense_in(&self) -> usize {
        self.conv_channels * self.image_size * self.image_size
    }

    /// Gradient-normalization shift for the fixed-point conv kernel
    /// gradient: ≈log₂(H·W), the length of the spatial reduction. The
    /// barrel shift at the multiplier output keeps the 32-bit Q8.24
    /// accumulator from wrapping (`qnn`/`sim` only; the float path uses
    /// true gradients + norm clipping). See `Fx::mul_acc_shifted`.
    pub fn kgrad_shift(&self) -> u32 {
        (self.image_size * self.image_size).next_power_of_two().trailing_zeros()
    }

    /// Gradient-normalization shift for the fixed-point fused dense
    /// weight update: ≈½·log₂(fan-in). Unlike the conv kernel gradient
    /// this product never wraps (no reduction), but its magnitude —
    /// activation (≤ 8) × loss gradient — is orders above the useful
    /// weight scale (~√(1/fan-in)), and at batch 1 the un-normalized
    /// update drives W into saturation over a long GDumb epoch
    /// (EXPERIMENTS.md E5). The same product-bus barrel shift fixes it.
    pub fn dense_grad_shift(&self) -> u32 {
        self.dense_in().next_power_of_two().trailing_zeros() / 2
    }

    /// Total trainable parameters.
    pub fn param_count(&self) -> usize {
        self.conv_channels * self.in_channels * 9
            + self.conv_channels * self.conv_channels * 9
            + self.dense_in() * self.num_classes
    }
}

/// Trainable parameters.
#[derive(Clone, Debug)]
pub struct Params {
    pub k1: Tensor<f32>, // (C, in, 3, 3)
    pub k2: Tensor<f32>, // (C, C, 3, 3)
    pub w: Tensor<f32>,  // (C*H*W, classes)
}

/// Per-parameter gradients from one backward pass.
#[derive(Clone, Debug)]
pub struct Gradients {
    pub k1: Tensor<f32>,
    pub k2: Tensor<f32>,
    pub w: Tensor<f32>,
}

/// Intermediate activations needed by the backward pass (the paper's
/// "Partial Feature memory" holds exactly these).
pub struct ForwardCache {
    pub x: Tensor<f32>,
    pub z1: Tensor<f32>, // conv1 pre-activation
    pub a1: Tensor<f32>, // relu(z1)
    pub z2: Tensor<f32>, // conv2 pre-activation
    pub a2: Tensor<f32>, // relu(z2), flattened into dense
    pub logits: Vec<f32>,
}

/// Result of a single train step.
#[derive(Clone, Debug)]
pub struct TrainOutput {
    pub loss: f32,
    pub correct: bool,
}

pub struct Model {
    pub config: ModelConfig,
    pub params: Params,
    /// Compute core for conv/dense (default: naive reference loops).
    pub engine: Engine,
}

impl Model {
    /// Fresh model with He-uniform init, deterministic in `seed`.
    pub fn new(config: ModelConfig, seed: u64) -> Model {
        let mut rng = Pcg32::new(seed, 100);
        let params = Params {
            k1: super::init::conv_kernel(
                &mut rng,
                config.conv_channels,
                config.in_channels,
                3,
                3,
            ),
            k2: super::init::conv_kernel(
                &mut rng,
                config.conv_channels,
                config.conv_channels,
                3,
                3,
            ),
            w: super::init::dense_weights(&mut rng, config.dense_in(), config.num_classes),
        };
        Model { config, params, engine: Engine::Naive }
    }

    pub fn from_params(config: ModelConfig, params: Params) -> Model {
        assert_eq!(
            params.w.shape(),
            &Shape::d2(config.dense_in(), config.num_classes)
        );
        Model { config, params, engine: Engine::Naive }
    }

    /// Select the compute core (builder-style; parameters are untouched).
    pub fn with_engine(mut self, engine: Engine) -> Model {
        self.engine = engine;
        self
    }

    // Engine dispatch: one seam per layer computation, so the forward
    // and backward passes read identically for both cores.

    fn conv_forward(&self, x: &Tensor<f32>, k: &Tensor<f32>) -> Tensor<f32> {
        match self.engine {
            Engine::Naive => conv::forward(x, k, 1, 1),
            Engine::Gemm => gemm::forward(x, k, 1, 1),
        }
    }

    fn conv_input_grad(&self, dy: &Tensor<f32>, k: &Tensor<f32>, x_shape: &Shape) -> Tensor<f32> {
        match self.engine {
            Engine::Naive => conv::input_grad(dy, k, x_shape, 1, 1),
            Engine::Gemm => gemm::input_grad(dy, k, x_shape, 1, 1),
        }
    }

    fn conv_kernel_grad(&self, dy: &Tensor<f32>, x: &Tensor<f32>, k_shape: &Shape) -> Tensor<f32> {
        match self.engine {
            Engine::Naive => conv::kernel_grad(dy, x, k_shape, 1, 1),
            Engine::Gemm => gemm::kernel_grad(dy, x, k_shape, 1, 1),
        }
    }

    fn dense_forward(&self, flat: &[f32]) -> Vec<f32> {
        match self.engine {
            Engine::Naive => dense::forward(flat, &self.params.w),
            Engine::Gemm => gemm::dense_forward(flat, &self.params.w),
        }
    }

    fn dense_input_grad(&self, dlogits: &[f32]) -> Vec<f32> {
        match self.engine {
            Engine::Naive => dense::input_grad(dlogits, &self.params.w),
            Engine::Gemm => gemm::dense_input_grad(dlogits, &self.params.w),
        }
    }

    fn dense_weight_grad(&self, dlogits: &[f32], flat: &[f32]) -> Tensor<f32> {
        match self.engine {
            Engine::Naive => dense::weight_grad(dlogits, flat),
            Engine::Gemm => gemm::dense_weight_grad(dlogits, flat),
        }
    }

    /// Forward pass keeping the caches backward needs.
    pub fn forward_cached(&self, x: &Tensor<f32>) -> ForwardCache {
        let z1 = self.conv_forward(x, &self.params.k1);
        let a1 = relu::forward(&z1);
        let z2 = self.conv_forward(&a1, &self.params.k2);
        let a2 = relu::forward(&z2);
        let logits = self.dense_forward(a2.data());
        ForwardCache { x: x.clone(), z1, a1, z2, a2, logits }
    }

    /// Inference only: logits.
    pub fn forward(&self, x: &Tensor<f32>) -> Vec<f32> {
        self.forward_cached(x).logits
    }

    /// Predicted class over the first `active_classes` logits.
    pub fn predict(&self, x: &Tensor<f32>, active_classes: usize) -> usize {
        loss::predict(&self.forward(x), active_classes)
    }

    /// Full backward pass from the CE gradient. Returns gradients for all
    /// parameters (does not mutate the model).
    pub fn backward(&self, cache: &ForwardCache, dlogits: &[f32]) -> Gradients {
        // Dense layer.
        let dw = self.dense_weight_grad(dlogits, cache.a2.data());
        let da2_flat = self.dense_input_grad(dlogits);
        let da2 = Tensor::from_vec(cache.a2.shape().clone(), da2_flat);

        // ReLU 2 + conv2.
        let dz2 = relu::backward(&da2, &cache.z2);
        let dk2 = self.conv_kernel_grad(&dz2, &cache.a1, self.params.k2.shape());
        let da1 = self.conv_input_grad(&dz2, &self.params.k2, cache.a1.shape());

        // ReLU 1 + conv1 (no input gradient needed at the first layer).
        let dz1 = relu::backward(&da1, &cache.z1);
        let dk1 = self.conv_kernel_grad(&dz1, &cache.x, self.params.k1.shape());

        Gradients { k1: dk1, k2: dk2, w: dw }
    }

    /// One SGD train step (batch 1) on `(x, label)` with the head masked to
    /// `active_classes`. Returns loss and top-1 correctness.
    pub fn train_step(
        &mut self,
        x: &Tensor<f32>,
        label: usize,
        active_classes: usize,
        lr: f32,
    ) -> TrainOutput {
        let cache = self.forward_cached(x);
        let (loss_value, dlogits) = loss::softmax_ce(&cache.logits, label, active_classes);
        let correct = loss::predict(&cache.logits, active_classes) == label;
        let mut grads = self.backward(&cache, &dlogits);
        sgd::clip_by_norm(&mut grads.k1, self.config.grad_clip);
        sgd::clip_by_norm(&mut grads.k2, self.config.grad_clip);
        sgd::clip_by_norm(&mut grads.w, self.config.grad_clip);
        self.apply(&grads, lr);
        TrainOutput { loss: loss_value, correct }
    }

    /// Apply pre-computed gradients.
    pub fn apply(&mut self, grads: &Gradients, lr: f32) {
        sgd::step(&mut self.params.k1, &grads.k1, lr);
        sgd::step(&mut self.params.k2, &grads.k2, lr);
        sgd::step(&mut self.params.w, &grads.w, lr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> ModelConfig {
        ModelConfig {
            in_channels: 3,
            image_size: 8,
            conv_channels: 4,
            num_classes: 4,
            grad_clip: f32::INFINITY,
        }
    }

    fn rand_image(seed: u64, cfg: &ModelConfig) -> Tensor<f32> {
        let mut rng = Pcg32::seeded(seed);
        let shape = Shape::d3(cfg.in_channels, cfg.image_size, cfg.image_size);
        let n = shape.numel();
        Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    #[test]
    fn shapes_consistent() {
        let cfg = ModelConfig::default();
        let m = Model::new(cfg.clone(), 1);
        assert_eq!(m.params.k1.shape().dims(), &[8, 3, 3, 3]);
        assert_eq!(m.params.k2.shape().dims(), &[8, 8, 3, 3]);
        assert_eq!(m.params.w.shape().dims(), &[8192, 10]);
        assert_eq!(cfg.param_count(), 8 * 3 * 9 + 8 * 8 * 9 + 8192 * 10);
    }

    #[test]
    fn train_step_reduces_loss_on_same_sample() {
        let cfg = tiny_config();
        let mut m = Model::new(cfg.clone(), 2);
        let x = rand_image(3, &cfg);
        let first = m.train_step(&x, 1, 4, 0.05).loss;
        let mut last = first;
        for _ in 0..20 {
            last = m.train_step(&x, 1, 4, 0.05).loss;
        }
        assert!(
            last < first * 0.5,
            "loss did not drop: first={first} last={last}"
        );
    }

    #[test]
    fn masked_classes_never_predicted() {
        let cfg = tiny_config();
        let m = Model::new(cfg.clone(), 4);
        let x = rand_image(5, &cfg);
        for _ in 0..5 {
            assert!(m.predict(&x, 2) < 2);
        }
    }

    #[test]
    fn deterministic_training() {
        let cfg = tiny_config();
        let x = rand_image(7, &cfg);
        let mut a = Model::new(cfg.clone(), 9);
        let mut b = Model::new(cfg.clone(), 9);
        for _ in 0..3 {
            let la = a.train_step(&x, 0, 4, 0.1).loss;
            let lb = b.train_step(&x, 0, 4, 0.1).loss;
            assert_eq!(la, lb);
        }
        assert_eq!(a.params.w.data(), b.params.w.data());
    }

    #[test]
    fn engines_share_init_and_agree_on_loss() {
        let cfg = tiny_config();
        let mut naive = Model::new(cfg.clone(), 2);
        let mut fast = Model::new(cfg.clone(), 2).with_engine(Engine::Gemm);
        assert_eq!(naive.params.w.data(), fast.params.w.data(), "init must not depend on engine");
        let x = rand_image(3, &cfg);
        for step in 0..5 {
            let ln = naive.train_step(&x, 1, 4, 0.05).loss;
            let lf = fast.train_step(&x, 1, 4, 0.05).loss;
            assert!(
                (ln - lf).abs() <= 1e-4 * (1.0 + ln.abs()),
                "step {step}: naive loss {ln} vs gemm loss {lf}"
            );
        }
        for (a, b) in naive.params.k1.data().iter().zip(fast.params.k1.data()) {
            assert!((a - b).abs() <= 1e-4, "k1 diverged: {a} vs {b}");
        }
    }

    #[test]
    fn backward_does_not_mutate() {
        let cfg = tiny_config();
        let m = Model::new(cfg.clone(), 11);
        let x = rand_image(13, &cfg);
        let before = m.params.w.data().to_vec();
        let cache = m.forward_cached(&x);
        let (_, dl) = super::loss::softmax_ce(&cache.logits, 0, 4);
        let _ = m.backward(&cache, &dl);
        assert_eq!(m.params.w.data(), &before[..]);
    }
}
