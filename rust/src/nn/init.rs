//! Weight initialization (He/Kaiming uniform — appropriate for ReLU nets
//! and keeps magnitudes inside Q4.12's [-8, 8) range by construction).

use crate::tensor::{Shape, Tensor};
use crate::util::rng::Pcg32;

/// He-uniform init for a conv kernel OIHW: bound = sqrt(6 / fan_in).
pub fn conv_kernel(rng: &mut Pcg32, cout: usize, cin: usize, kh: usize, kw: usize) -> Tensor<f32> {
    let fan_in = (cin * kh * kw) as f32;
    let bound = (6.0 / fan_in).sqrt();
    let shape = Shape::d4(cout, cin, kh, kw);
    let n = shape.numel();
    Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-bound, bound)).collect())
}

/// He-uniform init for dense weights (in, out): bound = sqrt(6 / n_in).
pub fn dense_weights(rng: &mut Pcg32, n_in: usize, n_out: usize) -> Tensor<f32> {
    let bound = (6.0 / n_in as f32).sqrt();
    let shape = Shape::d2(n_in, n_out);
    let n = shape.numel();
    Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-bound, bound)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_respected() {
        let mut rng = Pcg32::seeded(3);
        let k = conv_kernel(&mut rng, 8, 3, 3, 3);
        let bound = (6.0f32 / 27.0).sqrt();
        assert!(k.data().iter().all(|v| v.abs() <= bound));
        let w = dense_weights(&mut rng, 8192, 10);
        let bound = (6.0f32 / 8192.0).sqrt();
        assert!(w.data().iter().all(|v| v.abs() <= bound));
    }

    #[test]
    fn deterministic() {
        let a = conv_kernel(&mut Pcg32::seeded(1), 2, 2, 3, 3);
        let b = conv_kernel(&mut Pcg32::seeded(1), 2, 2, 3, 3);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn not_degenerate() {
        let mut rng = Pcg32::seeded(5);
        let k = conv_kernel(&mut rng, 4, 4, 3, 3);
        let distinct: std::collections::HashSet<u32> =
            k.data().iter().map(|v| v.to_bits()).collect();
        assert!(distinct.len() > k.data().len() / 2);
    }
}
