//! 2D convolution: forward, input-gradient and kernel-gradient (Eqs. 1–3).

use crate::tensor::{Shape, Tensor};

/// Output spatial size for a conv with the given geometry.
pub fn out_size(in_size: usize, k: usize, stride: usize, pad: usize) -> usize {
    (in_size + 2 * pad - k) / stride + 1
}

/// Forward convolution (paper Eq. 1): `x` CHW, `kernel` OIHW → CHW.
pub fn forward(x: &Tensor<f32>, kernel: &Tensor<f32>, stride: usize, pad: usize) -> Tensor<f32> {
    let [cin, h, w]: [usize; 3] = x.shape().dims().try_into().expect("x must be CHW");
    let kd = kernel.shape().dims();
    let (cout, kcin, kh, kw) = (kd[0], kd[1], kd[2], kd[3]);
    assert_eq!(cin, kcin, "channel mismatch: x {cin} vs kernel {kcin}");
    let oh = out_size(h, kh, stride, pad);
    let ow = out_size(w, kw, stride, pad);

    let mut out = Tensor::zeros(Shape::d3(cout, oh, ow));
    for oc in 0..cout {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0.0f32;
                for ic in 0..cin {
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            acc += x.at3(ic, iy as usize, ix as usize)
                                * kernel.at4(oc, ic, ky, kx);
                        }
                    }
                }
                out.set3(oc, oy, ox, acc);
            }
        }
    }
    out
}

/// Batched forward reference: the per-sample kernel looped over `B`
/// same-shape CHW inputs — the parity oracle for `nn::gemm`'s packed
/// single-GEMM batch path.
pub fn forward_batch(
    xs: &[&Tensor<f32>],
    kernel: &Tensor<f32>,
    stride: usize,
    pad: usize,
) -> Vec<Tensor<f32>> {
    assert!(!xs.is_empty(), "empty batch");
    xs.iter().map(|x| forward(x, kernel, stride, pad)).collect()
}

/// Gradient w.r.t. the input (paper Eq. 2): propagate `dy` back through
/// the kernel. `dy` is CHW over output geometry; result has `x`'s shape.
pub fn input_grad(
    dy: &Tensor<f32>,
    kernel: &Tensor<f32>,
    x_shape: &Shape,
    stride: usize,
    pad: usize,
) -> Tensor<f32> {
    let [cin, h, w]: [usize; 3] = x_shape.dims().try_into().expect("x_shape must be CHW");
    let kd = kernel.shape().dims();
    let (cout, kcin, kh, kw) = (kd[0], kd[1], kd[2], kd[3]);
    assert_eq!(cin, kcin);
    let dyd = dy.shape().dims();
    assert_eq!(dyd[0], cout, "dy channels");

    let mut dx = Tensor::zeros(x_shape.clone());
    for oc in 0..cout {
        for oy in 0..dyd[1] {
            for ox in 0..dyd[2] {
                let g = dy.at3(oc, oy, ox);
                if g == 0.0 {
                    continue;
                }
                for ic in 0..cin {
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let cur = dx.at3(ic, iy as usize, ix as usize);
                            dx.set3(
                                ic,
                                iy as usize,
                                ix as usize,
                                cur + g * kernel.at4(oc, ic, ky, kx),
                            );
                        }
                    }
                }
            }
        }
    }
    dx
}

/// Gradient w.r.t. the kernel (paper Eq. 3): correlate input with `dy`.
pub fn kernel_grad(
    dy: &Tensor<f32>,
    x: &Tensor<f32>,
    kernel_shape: &Shape,
    stride: usize,
    pad: usize,
) -> Tensor<f32> {
    let [cin, h, w]: [usize; 3] = x.shape().dims().try_into().expect("x must be CHW");
    let kd = kernel_shape.dims();
    let (cout, kcin, kh, kw) = (kd[0], kd[1], kd[2], kd[3]);
    assert_eq!(cin, kcin);
    let dyd = dy.shape().dims();
    assert_eq!(dyd[0], cout);

    let mut dk = Tensor::zeros(kernel_shape.clone());
    for oc in 0..cout {
        for oy in 0..dyd[1] {
            for ox in 0..dyd[2] {
                let g = dy.at3(oc, oy, ox);
                if g == 0.0 {
                    continue;
                }
                for ic in 0..cin {
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let cur = dk.at4(oc, ic, ky, kx);
                            dk.set4(
                                oc,
                                ic,
                                ky,
                                kx,
                                cur + g * x.at3(ic, iy as usize, ix as usize),
                            );
                        }
                    }
                }
            }
        }
    }
    dk
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg32;

    fn rand_tensor(rng: &mut Pcg32, shape: Shape) -> Tensor<f32> {
        let n = shape.numel();
        Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 kernel with weight 1 on a single channel is the identity.
        let mut rng = Pcg32::seeded(1);
        let x = rand_tensor(&mut rng, Shape::d3(1, 5, 5));
        let k = Tensor::from_vec(Shape::d4(1, 1, 1, 1), vec![1.0]);
        let y = forward(&x, &k, 1, 0);
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn known_3x3_convolution() {
        // All-ones 3x3 input, all-ones 3x3 kernel, pad 1:
        // corners see 4, edges 6, center 9.
        let x = Tensor::full(Shape::d3(1, 3, 3), 1.0f32);
        let k = Tensor::full(Shape::d4(1, 1, 3, 3), 1.0f32);
        let y = forward(&x, &k, 1, 1);
        assert_eq!(
            y.data(),
            &[4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]
        );
    }

    #[test]
    fn stride_two_downsamples() {
        let x = Tensor::full(Shape::d3(1, 4, 4), 1.0f32);
        let k = Tensor::full(Shape::d4(1, 1, 2, 2), 1.0f32);
        let y = forward(&x, &k, 2, 0);
        assert_eq!(y.shape().dims(), &[1, 2, 2]);
        assert!(y.data().iter().all(|&v| v == 4.0));
    }

    /// Finite-difference check of the analytic gradients.
    #[test]
    fn gradients_match_finite_difference() {
        check("conv grads ~ finite diff", 53, 8, |g| {
            let cin = g.usize_in(1, 2);
            let cout = g.usize_in(1, 2);
            let hw = g.usize_in(3, 5);
            let mut rng = g.rng().fork(9);
            let x = rand_tensor(&mut rng, Shape::d3(cin, hw, hw));
            let k = rand_tensor(&mut rng, Shape::d4(cout, cin, 3, 3));
            let dy_shape = forward(&x, &k, 1, 1).shape().clone();
            let dy = rand_tensor(&mut rng, dy_shape);

            // loss = <forward(x,k), dy>; check d loss / dx and d loss / dk.
            let dx = input_grad(&dy, &k, x.shape(), 1, 1);
            let dk = kernel_grad(&dy, &x, k.shape(), 1, 1);
            let eps = 1e-2f32;

            let loss = |x: &Tensor<f32>, k: &Tensor<f32>| -> f32 {
                forward(x, k, 1, 1)
                    .data()
                    .iter()
                    .zip(dy.data())
                    .map(|(a, b)| a * b)
                    .sum()
            };

            // spot-check a few coordinates of each gradient
            for probe in 0..4 {
                let i = (probe * 7 + 3) % x.data().len();
                let mut xp = x.clone();
                xp.data_mut()[i] += eps;
                let mut xm = x.clone();
                xm.data_mut()[i] -= eps;
                let fd = (loss(&xp, &k) - loss(&xm, &k)) / (2.0 * eps);
                assert!(
                    (fd - dx.data()[i]).abs() < 2e-2,
                    "dx[{i}]: fd={fd} analytic={}",
                    dx.data()[i]
                );

                let j = (probe * 5 + 1) % k.data().len();
                let mut kp = k.clone();
                kp.data_mut()[j] += eps;
                let mut km = k.clone();
                km.data_mut()[j] -= eps;
                let fd = (loss(&x, &kp) - loss(&x, &km)) / (2.0 * eps);
                assert!(
                    (fd - dk.data()[j]).abs() < 2e-2,
                    "dk[{j}]: fd={fd} analytic={}",
                    dk.data()[j]
                );
            }
        });
    }

    #[test]
    fn paper_shapes() {
        // conv on the paper's 32x32x8 feature with 8 filters keeps geometry.
        let mut rng = Pcg32::seeded(2);
        let x = rand_tensor(&mut rng, Shape::d3(8, 32, 32));
        let k = rand_tensor(&mut rng, Shape::d4(8, 8, 3, 3));
        let y = forward(&x, &k, 1, 1);
        assert_eq!(y.shape().dims(), &[8, 32, 32]);
    }
}
