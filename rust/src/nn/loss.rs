//! Softmax cross-entropy loss with class masking for class-incremental CL.
//!
//! The paper's CL setup grows the effective output head as tasks arrive
//! ("the output features' value is equal to the number of classes [...]
//! not static", §III-F-4). We keep the dense layer at the full 10-way
//! width and mask logits of classes not yet seen — numerically equivalent
//! to a growing head and what the dynamic `n` in the dense dataflow models.

/// Softmax over the first `active` logits; inactive entries get probability
/// zero. Numerically stabilized by max subtraction.
pub fn masked_softmax(logits: &[f32], active: usize) -> Vec<f32> {
    assert!(active >= 1 && active <= logits.len());
    let m = logits[..active].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits[..active].iter().map(|&l| (l - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    let mut p = vec![0.0; logits.len()];
    for (i, e) in exps.into_iter().enumerate() {
        p[i] = e / z;
    }
    p
}

/// Cross-entropy loss and its gradient w.r.t. the logits:
/// `dL/dlogit_i = p_i - 1[i == label]` (zero for masked classes).
pub fn softmax_ce(logits: &[f32], label: usize, active: usize) -> (f32, Vec<f32>) {
    assert!(label < active, "label {label} outside active head {active}");
    let p = masked_softmax(logits, active);
    let loss = -(p[label].max(1e-12)).ln();
    let mut grad = p;
    grad[label] -= 1.0;
    (loss, grad)
}

/// Argmax over the active head (prediction).
pub fn predict(logits: &[f32], active: usize) -> usize {
    logits[..active]
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// True when the top two logits over the active head are within `tol`
/// relative tolerance of each other. This is the only case where a
/// batched GEMM forward may legitimately flip a prediction relative to
/// the per-sample pass (the float engines' documented ≤ 1e-4 logit
/// contract) — the serving parity gates in `serve::bench` and
/// `tests/serve_parity.rs` share this one definition so the contract
/// cannot drift between them.
pub fn top2_near_tie(logits: &[f32], active: usize, tol: f32) -> bool {
    let mut head: Vec<f32> = logits[..active].to_vec();
    head.sort_by(|a, b| b.partial_cmp(a).unwrap());
    head.len() < 2 || head[0] - head[1] <= tol * (1.0 + head[0].abs())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn near_tie_gate_matches_its_contract() {
        // Clear winner: no flip allowed.
        assert!(!top2_near_tie(&[1.0, 0.5, 0.9], 3, 1e-4));
        // Exact tie and within-tolerance gap: flip permitted.
        assert!(top2_near_tie(&[1.0, 1.0, 0.0], 3, 1e-4));
        assert!(top2_near_tie(&[1.0, 1.0 - 1e-5, 0.0], 3, 1e-4));
        // The masked tail must not influence the verdict.
        assert!(!top2_near_tie(&[1.0, 0.5, 0.999_99], 2, 1e-4));
        // A one-class head cannot flip at all.
        assert!(top2_near_tie(&[3.0], 1, 1e-4));
    }

    #[test]
    fn softmax_sums_to_one_over_active() {
        let p = masked_softmax(&[1.0, 2.0, 3.0, 100.0], 3);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert_eq!(p[3], 0.0); // masked class untouched despite huge logit
    }

    #[test]
    fn loss_decreases_with_correct_confidence() {
        let (low, _) = softmax_ce(&[0.0, 0.0], 0, 2);
        let (high, _) = softmax_ce(&[5.0, 0.0], 0, 2);
        assert!(high < low);
    }

    #[test]
    fn gradient_sums_to_zero_and_matches_fd() {
        check("ce grad ~ fd", 67, 50, |g| {
            let n = g.usize_in(2, 10);
            let active = g.usize_in(2, n);
            let label = g.usize_in(0, active - 1);
            let logits: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let (_, grad) = softmax_ce(&logits, label, active);
            // gradient over active head sums to zero
            assert!(grad[..active].iter().sum::<f32>().abs() < 1e-5);
            // masked entries have zero gradient
            for i in active..n {
                assert_eq!(grad[i], 0.0);
            }
            // finite difference on one coordinate
            let i = g.usize_in(0, active - 1);
            let eps = 1e-3;
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let (fp, _) = softmax_ce(&lp, label, active);
            let (fm, _) = softmax_ce(&lm, label, active);
            let fd = (fp - fm) / (2.0 * eps);
            assert!((fd - grad[i]).abs() < 1e-3, "fd={fd} grad={}", grad[i]);
        });
    }

    #[test]
    fn predict_ignores_masked() {
        assert_eq!(predict(&[1.0, 2.0, 99.0], 2), 1);
    }
}
