//! f32 reference implementations of every layer TinyCL executes.
//!
//! This is the *software-level implementation* of the paper's workload
//! (§IV-A compares against TensorFlow-on-P100 running exactly this model:
//! Conv3×3(3→8) + ReLU + Conv3×3(8→8) + ReLU + Dense(8192→C)). It serves
//! as (1) the float oracle for the fixed-point `qnn`/`sim` paths, (2) the
//! fast backend for CL baselines, and (3) the cross-check target for the
//! AOT JAX artifacts executed via PJRT.
//!
//! Conventions: activations CHW, kernels OIHW (out, in, kh, kw), dense
//! weights (in, out) per paper Eq. (4). No biases — the paper's datapath
//! has no bias port (§III). The paper trains at batch size 1;
//! `Model::train_batch` additionally offers mean-gradient minibatches,
//! which the GEMM engine executes as batched packed GEMMs (`nn::gemm`).

pub mod conv;
pub mod dense;
pub mod gemm;
pub mod init;
pub mod loss;
pub mod model;
pub mod relu;
pub mod sgd;

pub use model::{
    fresh_head, BatchTrainOutput, Engine, Gradients, Model, ModelConfig, Params, TrainOutput,
    MAX_CUT,
};
