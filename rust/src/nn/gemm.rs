//! im2col + cache-blocked f32 GEMM — the `f32-fast` compute core.
//!
//! The naive kernels in [`super::conv`] walk a 6-deep per-element loop
//! with padding branches in the innermost body. This module restructures
//! the same three convolution computations (paper Eqs. 1–3) as matrix
//! multiplies over an im2col-packed input, the classic lowering every
//! fast CPU training stack uses (cf. PULP-TrainLib's blocked kernels):
//!
//! * forward:      `Y (Cout×N) = K (Cout×KD) · cols(X) (KD×N)`
//! * input grad:   `dcols (KD×N) = Kᵀ (KD×Cout) · dY (Cout×N)`, col2im
//! * kernel grad:  `dK (Cout×KD) = dY (Cout×N) · cols(X)ᵀ (N×KD)`
//!
//! with `KD = Cin·Kh·Kw` and `N = Oh·Ow`. The OIHW kernel tensor is
//! already a row-major `Cout×KD` matrix and the CHW output is already a
//! row-major `Cout×N` matrix, so packing is only needed on the input
//! side. All inner loops run over contiguous slices (axpy / unrolled
//! dot), which the compiler vectorizes; the GEMMs block the `N`
//! dimension into L1-sized panels.
//!
//! Numerics: same multiplies as the naive path but different summation
//! order, so results agree to float round-off (≤ 1e-4 relative — pinned
//! by `tests/gemm_vs_naive.rs` and the golden vectors), not bitwise.

use super::conv::out_size;
use crate::tensor::{Shape, Tensor};

/// Column-panel width for the blocked GEMMs: 256 f32 = 1 KiB per row
/// keeps a full B-panel plus the C row in L1 at the paper's geometry.
const PANEL: usize = 256;

/// `C (m×n) += A (m×k) · B (k×n)`, all row-major.
pub fn gemm_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A must be m×k");
    assert_eq!(b.len(), k * n, "B must be k×n");
    assert_eq!(c.len(), m * n, "C must be m×n");
    for j0 in (0..n).step_by(PANEL) {
        let j1 = (j0 + PANEL).min(n);
        for (a_row, c_row) in a.chunks_exact(k).zip(c.chunks_exact_mut(n)) {
            for (&av, b_row) in a_row.iter().zip(b.chunks_exact(n)) {
                if av == 0.0 {
                    continue;
                }
                for (cv, &bv) in c_row[j0..j1].iter_mut().zip(&b_row[j0..j1]) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// `C (k×n) += Aᵀ · B` where `A` is `m×k` and `B` is `m×n`, row-major.
/// (Transposition is implicit: A is read row by row, scattering into C
/// rows, so every inner loop still runs over contiguous memory.)
pub fn gemm_tn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A must be m×k");
    assert_eq!(b.len(), m * n, "B must be m×n");
    assert_eq!(c.len(), k * n, "C must be k×n");
    for (a_row, b_row) in a.chunks_exact(k).zip(b.chunks_exact(n)) {
        for (&av, c_row) in a_row.iter().zip(c.chunks_exact_mut(n)) {
            if av == 0.0 {
                continue;
            }
            for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                *cv += av * bv;
            }
        }
    }
}

/// `C (m×n) += A · Bᵀ` where `A` is `m×kd` and `B` is `n×kd`, row-major:
/// every C element is a dot product of two contiguous rows.
pub fn gemm_nt(m: usize, n: usize, kd: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * kd, "A must be m×kd");
    assert_eq!(b.len(), n * kd, "B must be n×kd");
    assert_eq!(c.len(), m * n, "C must be m×n");
    for (a_row, c_row) in a.chunks_exact(kd).zip(c.chunks_exact_mut(n)) {
        for (cv, b_row) in c_row.iter_mut().zip(b.chunks_exact(kd)) {
            *cv += dot(a_row, b_row);
        }
    }
}

/// Unrolled dot product: 8 independent accumulators break the sequential
/// FP-add dependency chain so the loop pipelines/vectorizes.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let a8 = a.chunks_exact(8);
    let b8 = b.chunks_exact(8);
    let ra = a8.remainder();
    let rb = b8.remainder();
    let mut acc = [0.0f32; 8];
    for (ca, cb) in a8.zip(b8) {
        for l in 0..8 {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    tail + ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))
}

/// Pack a CHW input into the `(Cin·Kh·Kw) × (Oh·Ow)` column matrix for a
/// `Kh×Kw` convolution. Out-of-image taps (padding) stay zero. Returns
/// the matrix and the output spatial size.
pub fn im2col(
    x: &Tensor<f32>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> (Vec<f32>, usize, usize) {
    let [cin, h, w]: [usize; 3] = x.shape().dims().try_into().expect("x must be CHW");
    let oh = out_size(h, kh, stride, pad);
    let ow = out_size(w, kw, stride, pad);
    let n = oh * ow;
    let mut cols = vec![0.0f32; cin * kh * kw * n];
    let xd = x.data();
    let mut row = 0;
    for ic in 0..cin {
        let plane = &xd[ic * h * w..(ic + 1) * h * w];
        for ky in 0..kh {
            for kx in 0..kw {
                let dest = &mut cols[row * n..(row + 1) * n];
                for oy in 0..oh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let src = &plane[iy as usize * w..iy as usize * w + w];
                    let drow = &mut dest[oy * ow..(oy + 1) * ow];
                    for ox in 0..ow {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix >= 0 && ix < w as isize {
                            drow[ox] = src[ix as usize];
                        }
                    }
                }
                row += 1;
            }
        }
    }
    (cols, oh, ow)
}

/// Scatter-add a `(Cin·Kh·Kw) × (Oh·Ow)` column-gradient matrix back
/// into a CHW input gradient (the adjoint of [`im2col`]).
#[allow(clippy::too_many_arguments)]
fn col2im(
    dcols: &[f32],
    cin: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
) -> Vec<f32> {
    let n = oh * ow;
    let mut dx = vec![0.0f32; cin * h * w];
    let mut row = 0;
    for ic in 0..cin {
        for ky in 0..kh {
            for kx in 0..kw {
                let src = &dcols[row * n..(row + 1) * n];
                let plane = &mut dx[ic * h * w..(ic + 1) * h * w];
                for oy in 0..oh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let drow = &mut plane[iy as usize * w..iy as usize * w + w];
                    let srow = &src[oy * ow..(oy + 1) * ow];
                    for ox in 0..ow {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix >= 0 && ix < w as isize {
                            drow[ix as usize] += srow[ox];
                        }
                    }
                }
                row += 1;
            }
        }
    }
    dx
}

/// Forward convolution (paper Eq. 1) via im2col + GEMM. Drop-in
/// replacement for [`super::conv::forward`].
pub fn forward(x: &Tensor<f32>, kernel: &Tensor<f32>, stride: usize, pad: usize) -> Tensor<f32> {
    let [cin, _, _]: [usize; 3] = x.shape().dims().try_into().expect("x must be CHW");
    let kd = kernel.shape().dims();
    let (cout, kcin, kh, kw) = (kd[0], kd[1], kd[2], kd[3]);
    assert_eq!(cin, kcin, "channel mismatch: x {cin} vs kernel {kcin}");
    let (cols, oh, ow) = im2col(x, kh, kw, stride, pad);
    let n = oh * ow;
    let mut out = vec![0.0f32; cout * n];
    gemm_nn(cout, cin * kh * kw, n, kernel.data(), &cols, &mut out);
    Tensor::from_vec(Shape::d3(cout, oh, ow), out)
}

/// Gradient w.r.t. the input (paper Eq. 2) via GEMM + col2im. Drop-in
/// replacement for [`super::conv::input_grad`].
pub fn input_grad(
    dy: &Tensor<f32>,
    kernel: &Tensor<f32>,
    x_shape: &Shape,
    stride: usize,
    pad: usize,
) -> Tensor<f32> {
    let [cin, h, w]: [usize; 3] = x_shape.dims().try_into().expect("x_shape must be CHW");
    let kd = kernel.shape().dims();
    let (cout, kcin, kh, kw) = (kd[0], kd[1], kd[2], kd[3]);
    assert_eq!(cin, kcin);
    let dyd = dy.shape().dims();
    assert_eq!(dyd[0], cout, "dy channels");
    let (oh, ow) = (dyd[1], dyd[2]);
    debug_assert_eq!(oh, out_size(h, kh, stride, pad));
    debug_assert_eq!(ow, out_size(w, kw, stride, pad));
    let n = oh * ow;
    let kdim = cin * kh * kw;
    let mut dcols = vec![0.0f32; kdim * n];
    gemm_tn(cout, kdim, n, kernel.data(), dy.data(), &mut dcols);
    let dx = col2im(&dcols, cin, h, w, kh, kw, stride, pad, oh, ow);
    Tensor::from_vec(x_shape.clone(), dx)
}

/// Gradient w.r.t. the kernel (paper Eq. 3) via im2col + GEMM. Drop-in
/// replacement for [`super::conv::kernel_grad`].
pub fn kernel_grad(
    dy: &Tensor<f32>,
    x: &Tensor<f32>,
    kernel_shape: &Shape,
    stride: usize,
    pad: usize,
) -> Tensor<f32> {
    let [cin, _, _]: [usize; 3] = x.shape().dims().try_into().expect("x must be CHW");
    let kd = kernel_shape.dims();
    let (cout, kcin, kh, kw) = (kd[0], kd[1], kd[2], kd[3]);
    assert_eq!(cin, kcin);
    let (cols, oh, ow) = im2col(x, kh, kw, stride, pad);
    let dyd = dy.shape().dims();
    assert_eq!(dyd[0], cout);
    assert_eq!((dyd[1], dyd[2]), (oh, ow), "dy geometry vs conv geometry");
    let kdim = cin * kh * kw;
    let mut dk = vec![0.0f32; cout * kdim];
    gemm_nt(cout, kdim, oh * ow, dy.data(), &cols, &mut dk);
    Tensor::from_vec(kernel_shape.clone(), dk)
}

/// Dense forward (Eq. 4) through the GEMM core: `y (1×Nout) = x (1×Nin) ·
/// W (Nin×Nout)`.
pub fn dense_forward(x: &[f32], w: &Tensor<f32>) -> Vec<f32> {
    let [n_in, n_out]: [usize; 2] = w.shape().dims().try_into().expect("w must be 2D");
    assert_eq!(x.len(), n_in, "input length {} vs weight rows {n_in}", x.len());
    let mut y = vec![0.0f32; n_out];
    gemm_nn(1, n_in, n_out, x, w.data(), &mut y);
    y
}

/// Dense input gradient (Eq. 5): `dX (Nin) = W (Nin×Nout) · dY (Nout)` —
/// one contiguous-row dot per input element.
pub fn dense_input_grad(dy: &[f32], w: &Tensor<f32>) -> Vec<f32> {
    let [n_in, n_out]: [usize; 2] = w.shape().dims().try_into().expect("w must be 2D");
    assert_eq!(dy.len(), n_out);
    let dx: Vec<f32> = w.data().chunks_exact(n_out).map(|row| dot(row, dy)).collect();
    debug_assert_eq!(dx.len(), n_in);
    dx
}

/// Dense weight gradient (Eq. 6): rank-1 outer product `dW = x ⊗ dY`,
/// written row-at-a-time (axpy form, skipping post-ReLU zeros).
pub fn dense_weight_grad(dy: &[f32], x: &[f32]) -> Tensor<f32> {
    let n_out = dy.len();
    let mut dw = vec![0.0f32; x.len() * n_out];
    for (&xi, dw_row) in x.iter().zip(dw.chunks_exact_mut(n_out)) {
        if xi == 0.0 {
            continue;
        }
        for (d, &g) in dw_row.iter_mut().zip(dy) {
            *d = xi * g;
        }
    }
    Tensor::from_vec(Shape::d2(x.len(), n_out), dw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{conv, dense};
    use crate::util::rng::Pcg32;

    fn rand_tensor(rng: &mut Pcg32, shape: Shape) -> Tensor<f32> {
        let n = shape.numel();
        Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length mismatch");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                "{what}[{i}]: gemm {x} vs naive {y}"
            );
        }
    }

    #[test]
    fn gemm_nn_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        gemm_nn(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn gemm_tn_is_a_transpose_times_b() {
        // Aᵀ·B with A = [1 2; 3 4] (2×2), B = [5 6; 7 8]:
        // Aᵀ = [1 3; 2 4] → [1·5+3·7, 1·6+3·8; 2·5+4·7, 2·6+4·8]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        gemm_tn(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, [26.0, 30.0, 38.0, 44.0]);
    }

    #[test]
    fn gemm_nt_is_a_times_b_transpose() {
        // A·Bᵀ with A = [1 2; 3 4], B = [5 6; 7 8]:
        // [1·5+2·6, 1·7+2·8; 3·5+4·6, 3·7+4·8]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        gemm_nt(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, [17.0, 23.0, 39.0, 53.0]);
    }

    #[test]
    fn gemm_panels_cover_wide_matrices() {
        // n > PANEL exercises the panel loop. C = A·B with A = ones(1×2),
        // B = ones(2×n) → every C element is 2.
        let n = PANEL * 2 + 37;
        let a = vec![1.0f32; 2];
        let b = vec![1.0f32; 2 * n];
        let mut c = vec![0.0f32; n];
        gemm_nn(1, 2, n, &a, &b, &mut c);
        assert!(c.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn dot_matches_reference_on_odd_lengths() {
        let mut rng = Pcg32::seeded(5);
        for len in [0usize, 1, 7, 8, 9, 64, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let expect: f64 =
                a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            assert!((dot(&a, &b) as f64 - expect).abs() < 1e-4, "len {len}");
        }
    }

    #[test]
    fn identity_kernel_passthrough() {
        let mut rng = Pcg32::seeded(1);
        let x = rand_tensor(&mut rng, Shape::d3(1, 5, 5));
        let k = Tensor::from_vec(Shape::d4(1, 1, 1, 1), vec![1.0]);
        let y = forward(&x, &k, 1, 0);
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn known_3x3_convolution() {
        let x = Tensor::full(Shape::d3(1, 3, 3), 1.0f32);
        let k = Tensor::full(Shape::d4(1, 1, 3, 3), 1.0f32);
        let y = forward(&x, &k, 1, 1);
        assert_eq!(y.data(), &[4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn stride_two_matches_naive() {
        let mut rng = Pcg32::seeded(9);
        let x = rand_tensor(&mut rng, Shape::d3(2, 7, 7));
        let k = rand_tensor(&mut rng, Shape::d4(3, 2, 3, 3));
        let fast = forward(&x, &k, 2, 1);
        let naive = conv::forward(&x, &k, 2, 1);
        assert_eq!(fast.shape(), naive.shape());
        assert_close(fast.data(), naive.data(), 1e-5, "stride-2 forward");
    }

    #[test]
    fn paper_geometry_matches_naive_all_three_ops() {
        let mut rng = Pcg32::seeded(2);
        let x = rand_tensor(&mut rng, Shape::d3(8, 32, 32));
        let k = rand_tensor(&mut rng, Shape::d4(8, 8, 3, 3));
        let y_fast = forward(&x, &k, 1, 1);
        let y_naive = conv::forward(&x, &k, 1, 1);
        assert_close(y_fast.data(), y_naive.data(), 1e-4, "forward");

        let dy = rand_tensor(&mut rng, y_naive.shape().clone());
        let dx_fast = input_grad(&dy, &k, x.shape(), 1, 1);
        let dx_naive = conv::input_grad(&dy, &k, x.shape(), 1, 1);
        assert_close(dx_fast.data(), dx_naive.data(), 1e-4, "input_grad");

        let dk_fast = kernel_grad(&dy, &x, k.shape(), 1, 1);
        let dk_naive = conv::kernel_grad(&dy, &x, k.shape(), 1, 1);
        assert_close(dk_fast.data(), dk_naive.data(), 1e-4, "kernel_grad");
    }

    #[test]
    fn dense_ops_match_naive() {
        let mut rng = Pcg32::seeded(3);
        let (n_in, n_out) = (64, 10);
        let x: Vec<f32> = (0..n_in).map(|_| rng.range_f32(-1.0, 1.0).max(0.0)).collect();
        let w = rand_tensor(&mut rng, Shape::d2(n_in, n_out));
        let dy: Vec<f32> = (0..n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();

        assert_close(&dense_forward(&x, &w), &dense::forward(&x, &w), 1e-5, "dense fwd");
        assert_close(
            &dense_input_grad(&dy, &w),
            &dense::input_grad(&dy, &w),
            1e-5,
            "dense dX",
        );
        assert_close(
            dense_weight_grad(&dy, &x).data(),
            dense::weight_grad(&dy, &x).data(),
            1e-5,
            "dense dW",
        );
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), c> == <x, col2im(c)> — the defining adjoint
        // identity that makes input_grad the exact transpose of forward.
        let mut rng = Pcg32::seeded(11);
        let x = rand_tensor(&mut rng, Shape::d3(2, 5, 5));
        let (cols, oh, ow) = im2col(&x, 3, 3, 1, 1);
        let c: Vec<f32> = (0..cols.len()).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let lhs: f64 = cols.iter().zip(&c).map(|(&a, &b)| a as f64 * b as f64).sum();
        let back = col2im(&c, 2, 5, 5, 3, 3, 1, 1, oh, ow);
        let rhs: f64 = x.data().iter().zip(&back).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3, "adjoint identity violated: {lhs} vs {rhs}");
    }
}
