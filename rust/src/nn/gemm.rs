//! im2col + cache-blocked f32 GEMM — the `f32-fast` compute core.
//!
//! The naive kernels in [`super::conv`] walk a 6-deep per-element loop
//! with padding branches in the innermost body. This module restructures
//! the same three convolution computations (paper Eqs. 1–3) as matrix
//! multiplies over an im2col-packed input, the classic lowering every
//! fast CPU training stack uses (cf. PULP-TrainLib's blocked kernels):
//!
//! * forward:      `Y (Cout×N) = K (Cout×KD) · cols(X) (KD×N)`
//! * input grad:   `dcols (KD×N) = Kᵀ (KD×Cout) · dY (Cout×N)`, col2im
//! * kernel grad:  `dK (Cout×KD) = dY (Cout×N) · cols(X)ᵀ (N×KD)`
//!
//! with `KD = Cin·Kh·Kw` and `N = Oh·Ow`. The OIHW kernel tensor is
//! already a row-major `Cout×KD` matrix and the CHW output is already a
//! row-major `Cout×N` matrix, so packing is only needed on the input
//! side. All inner loops run over contiguous slices (axpy / unrolled
//! dot), which the compiler vectorizes; the GEMMs block the `N`
//! dimension into L1-sized panels.
//!
//! **Batching (PR 2).** The `*_batch` functions generalize the lowering
//! to NCHW minibatches: all `B` images are packed into one
//! `(Cin·Kh·Kw) × (B·Oh·Ow)` column matrix, so each conv pass is a
//! single large GEMM amortized across the batch, and the dense layer is
//! a true `B×in · in×out` GEMM. Between layers, batched activations
//! live in a *channel-major packed* layout — a row-major `(C, B·H·W)`
//! matrix whose row `c` holds image 0's plane, then image 1's, … — which
//! is exactly the GEMM output layout, so no transposes happen between
//! convolutions. The dense layer needs sample-major rows; the
//! [`packed_to_rows`]/[`rows_to_packed`] pair converts (B·C memcpys).
//!
//! **Threading (PR 2, pooled in PR 3).** `gemm_nn_mt`/`gemm_tn_mt`/
//! `gemm_nt_mt` shard the output-column loop across `threads` workers
//! of the process-wide persistent pool ([`crate::util::pool`] — no
//! external deps; PR 2 respawned scoped threads per call, which cost
//! tens of microseconds per GEMM). Every worker owns a disjoint
//! contiguous column range of `C`, so there are no reduction races and
//! no atomics, and the per-element summation order is independent of
//! the sharding: **threads=N is bit-identical to threads=1** (asserted
//! by unit tests and `tests/batched_parity.rs`). Problems below
//! [`MT_MIN_MACS`] multiply-accumulates stay single-threaded so tiny
//! layers don't pay dispatch overhead.
//!
//! Numerics: same multiplies as the naive path but different summation
//! order, so results agree to float round-off (≤ 1e-4 relative — pinned
//! by `tests/gemm_vs_naive.rs` and the golden vectors), not bitwise.
//! (The *integer* GEMM core in `fixed::gemm` shares this module's
//! blocking and sharding scheme but is exactly bitwise — wrapping i32
//! sums are associative.)

use super::conv::out_size;
use crate::tensor::{Shape, Tensor};
use crate::util::pool::{self, col_ranges, plan_workers, SendPtr};

pub use crate::util::pool::MT_MIN_MACS;

/// Column-panel width for the blocked GEMMs: 256 f32 = 1 KiB per row
/// keeps a full B-panel plus the C row in L1 at the paper's geometry.
const PANEL: usize = 256;

/// Microkernel tile height: rows of A (and C) per register tile.
pub const MR: usize = 4;

/// Microkernel tile width: columns of C per register tile.
pub const NR: usize = 8;

/// An `m×k` A operand repacked into microkernel-tile order: row blocks
/// of [`MR`] rows, each block stored column-major
/// (`data[i0*k + kk*mr_i + mi] = a[(i0+mi)*k + kk]`) so the NN and
/// fused microkernels stream A with unit stride. Packing is pure data
/// movement — the tiled kernels run the identical per-output k-ascending
/// FP-add chain either way, so results are bit-identical. Weight
/// snapshots (serving replicas) pack once at `clone_replica` and reuse
/// across every forward call.
#[derive(Clone, Debug)]
pub struct PackedA {
    m: usize,
    k: usize,
    data: Vec<f32>,
}

impl PackedA {
    pub fn pack(m: usize, k: usize, a: &[f32]) -> PackedA {
        assert_eq!(a.len(), m * k, "A must be m×k");
        let mut data = vec![0.0f32; m * k];
        let mut w = 0;
        for i0 in (0..m).step_by(MR) {
            let mr_i = MR.min(m - i0);
            for kk in 0..k {
                for mi in 0..mr_i {
                    data[w] = a[(i0 + mi) * k + kk];
                    w += 1;
                }
            }
        }
        PackedA { m, k, data }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// True when this pack is bit-for-bit the pack of `a` — the
    /// freshness check behind the packed-weight-cache debug asserts.
    pub fn matches(&self, m: usize, k: usize, a: &[f32]) -> bool {
        if self.m != m || self.k != k || a.len() != m * k {
            return false;
        }
        let mut r = 0;
        for i0 in (0..m).step_by(MR) {
            let mr_i = MR.min(m - i0);
            for kk in 0..k {
                for mi in 0..mr_i {
                    if self.data[r].to_bits() != a[(i0 + mi) * k + kk].to_bits() {
                        return false;
                    }
                    r += 1;
                }
            }
        }
        true
    }
}

/// `C (m×n) += A (m×k) · B (k×n)`, all row-major, single-threaded.
pub fn gemm_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    gemm_nn_mt(m, k, n, a, b, c, 1);
}

/// [`gemm_nn`] with the output columns sharded across up to `threads`
/// persistent-pool workers. Packs A into tile order per call (O(m·k),
/// negligible next to the O(m·k·n) multiply). Bit-identical to the
/// single-threaded path: each output element is one k-ascending FP-add
/// chain regardless of tiling or sharding.
pub fn gemm_nn_mt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A must be m×k");
    assert_eq!(b.len(), k * n, "B must be k×n");
    assert_eq!(c.len(), m * n, "C must be m×n");
    if m == 0 || n == 0 {
        return;
    }
    let pa = PackedA::pack(m, k, a);
    gemm_nn_packed_mt(&pa, n, b, c, threads);
}

/// `C (m×n) += A · B (k×n)` with A pre-packed in tile order — the
/// snapshot-packed serving path. Bit-identical to [`gemm_nn_mt`].
pub fn gemm_nn_packed_mt(pa: &PackedA, n: usize, b: &[f32], c: &mut [f32], threads: usize) {
    let (m, k) = (pa.m, pa.k);
    assert_eq!(b.len(), k * n, "B must be k×n");
    assert_eq!(c.len(), m * n, "C must be m×n");
    if m == 0 || n == 0 {
        return;
    }
    crate::obs::count_gemm((m * k * n) as u64);
    let workers = plan_workers(threads, m * k * n, n);
    let ptr = SendPtr(c.as_mut_ptr());
    if workers <= 1 {
        gemm_nn_packed_range(m, k, n, &pa.data, b, ptr, 0, n);
        return;
    }
    let ranges = col_ranges(n, workers);
    pool::run(ranges.len(), |wi| {
        let (lo, hi) = ranges[wi];
        gemm_nn_packed_range(m, k, n, &pa.data, b, ptr, lo, hi);
    });
}

/// One `MR_`×[`NR`] register tile of the packed NN kernel: accumulators
/// load from C, run the k-ascending FP-add chain, store back — the same
/// per-output chain as a scalar axpy loop over a zero-initialized C, so
/// the tiling is bit-invisible.
///
/// # Safety
/// The caller must own output columns `jj..jj+NR` of rows `i0..i0+MR_`,
/// and `ap` must be the packed block for those rows (length `MR_*k`).
#[inline(always)]
unsafe fn nn_tile<const MR_: usize>(
    k: usize,
    n: usize,
    ap: &[f32],
    b: &[f32],
    c: *mut f32,
    i0: usize,
    jj: usize,
) {
    let mut acc = [[0.0f32; NR]; MR_];
    for (mi, row) in acc.iter_mut().enumerate() {
        let crow = c.add((i0 + mi) * n + jj);
        for (u, v) in row.iter_mut().enumerate() {
            *v = *crow.add(u);
        }
    }
    for kk in 0..k {
        let bq = &b[kk * n + jj..kk * n + jj + NR];
        for (mi, row) in acc.iter_mut().enumerate() {
            let av = ap[kk * MR_ + mi];
            for (v, &bv) in row.iter_mut().zip(bq) {
                *v += av * bv;
            }
        }
    }
    for (mi, row) in acc.iter().enumerate() {
        let crow = c.add((i0 + mi) * n + jj);
        for (u, &v) in row.iter().enumerate() {
            *crow.add(u) = v;
        }
    }
}

/// Panel-blocked tiled NN kernel over output columns `lo..hi`, reading
/// A in [`PackedA`] order. Every output element's k-loop order never
/// depends on `(lo, hi)` or the tile shape, so any column sharding
/// produces bit-identical sums.
#[allow(clippy::too_many_arguments)]
fn gemm_nn_packed_range(
    m: usize,
    k: usize,
    n: usize,
    pa: &[f32],
    b: &[f32],
    c: SendPtr<f32>,
    lo: usize,
    hi: usize,
) {
    for j0 in (lo..hi).step_by(PANEL) {
        let j1 = (j0 + PANEL).min(hi);
        for i0 in (0..m).step_by(MR) {
            let mr_i = MR.min(m - i0);
            let ap = &pa[i0 * k..i0 * k + mr_i * k];
            let mut jj = j0;
            // Safety: this worker is the only writer of columns lo..hi.
            unsafe {
                while jj + NR <= j1 {
                    match mr_i {
                        4 => nn_tile::<4>(k, n, ap, b, c.0, i0, jj),
                        3 => nn_tile::<3>(k, n, ap, b, c.0, i0, jj),
                        2 => nn_tile::<2>(k, n, ap, b, c.0, i0, jj),
                        _ => nn_tile::<1>(k, n, ap, b, c.0, i0, jj),
                    }
                    jj += NR;
                }
            }
            for j in jj..j1 {
                for mi in 0..mr_i {
                    // Safety: as above — sole writer of this column range.
                    let cv = unsafe { &mut *c.0.add((i0 + mi) * n + j) };
                    let mut acc = *cv;
                    for kk in 0..k {
                        acc += ap[kk * mr_i + mi] * b[kk * n + j];
                    }
                    *cv = acc;
                }
            }
        }
    }
}

/// The pre-tiling NN kernel, kept verbatim: scalar axpy rows that
/// **skip zero A operands**. The skip branch mispredicts on dense A
/// (conv kernels), but wins when A is a sparse post-ReLU activation
/// matrix and n is small — the dense head's `batch×8192 · 8192×10`,
/// where one skipped row avoids the whole 10-wide axpy. The `gemm`
/// micro-rung in `benches/speedup.rs` pins that choice. Bit-identical
/// to [`gemm_nn_mt`]: with C zero-initialized (+0.0, as every caller
/// does), adding the skipped `±0.0` products is an exact FP identity.
pub fn gemm_nn_skipa_mt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A must be m×k");
    assert_eq!(b.len(), k * n, "B must be k×n");
    assert_eq!(c.len(), m * n, "C must be m×n");
    if m == 0 || n == 0 {
        return;
    }
    crate::obs::count_gemm((m * k * n) as u64);
    let workers = plan_workers(threads, m * k * n, n);
    let ptr = SendPtr(c.as_mut_ptr());
    if workers <= 1 {
        gemm_nn_skipa_range(m, k, n, a, b, ptr, 0, n);
        return;
    }
    let ranges = col_ranges(n, workers);
    pool::run(ranges.len(), |wi| {
        let (lo, hi) = ranges[wi];
        gemm_nn_skipa_range(m, k, n, a, b, ptr, lo, hi);
    });
}

/// Panel-blocked zero-skipping NN kernel over output columns `lo..hi`.
#[allow(clippy::too_many_arguments)]
fn gemm_nn_skipa_range(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: SendPtr<f32>,
    lo: usize,
    hi: usize,
) {
    for j0 in (lo..hi).step_by(PANEL) {
        let j1 = (j0 + PANEL).min(hi);
        for i in 0..m {
            let a_row = &a[i * k..(i + 1) * k];
            // Safety: this worker is the only writer of columns lo..hi.
            let c_row = unsafe { std::slice::from_raw_parts_mut(c.0.add(i * n + j0), j1 - j0) };
            for (kk, &av) in a_row.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let b_row = &b[kk * n + j0..kk * n + j1];
                for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// Fused-epilogue variant of [`nn_tile`]: accumulators start at `0.0`
/// and the optional ReLU (`max(0.0)`) runs at the C-tile store.
///
/// # Safety
/// Same contract as [`nn_tile`], with `out` the `m×n` output.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn nn_tile_fused<const MR_: usize>(
    k: usize,
    n: usize,
    ap: &[f32],
    b: &[f32],
    out: *mut f32,
    i0: usize,
    jj: usize,
    relu: bool,
) {
    let mut acc = [[0.0f32; NR]; MR_];
    for kk in 0..k {
        let bq = &b[kk * n + jj..kk * n + jj + NR];
        for (mi, row) in acc.iter_mut().enumerate() {
            let av = ap[kk * MR_ + mi];
            for (v, &bv) in row.iter_mut().zip(bq) {
                *v += av * bv;
            }
        }
    }
    for (mi, row) in acc.iter().enumerate() {
        let orow = out.add((i0 + mi) * n + jj);
        for (u, &v) in row.iter().enumerate() {
            *orow.add(u) = if relu { v.max(0.0) } else { v };
        }
    }
}

/// Tiled fused NN kernel over output columns `lo..hi`.
#[allow(clippy::too_many_arguments)]
fn gemm_nn_fused_range(
    m: usize,
    k: usize,
    n: usize,
    pa: &[f32],
    b: &[f32],
    out: SendPtr<f32>,
    relu: bool,
    lo: usize,
    hi: usize,
) {
    for j0 in (lo..hi).step_by(PANEL) {
        let j1 = (j0 + PANEL).min(hi);
        for i0 in (0..m).step_by(MR) {
            let mr_i = MR.min(m - i0);
            let ap = &pa[i0 * k..i0 * k + mr_i * k];
            let mut jj = j0;
            // Safety: this worker is the only writer of columns lo..hi.
            unsafe {
                while jj + NR <= j1 {
                    match mr_i {
                        4 => nn_tile_fused::<4>(k, n, ap, b, out.0, i0, jj, relu),
                        3 => nn_tile_fused::<3>(k, n, ap, b, out.0, i0, jj, relu),
                        2 => nn_tile_fused::<2>(k, n, ap, b, out.0, i0, jj, relu),
                        _ => nn_tile_fused::<1>(k, n, ap, b, out.0, i0, jj, relu),
                    }
                    jj += NR;
                }
            }
            for j in jj..j1 {
                for mi in 0..mr_i {
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        acc += ap[kk * mr_i + mi] * b[kk * n + j];
                    }
                    // Safety: as above — sole writer of this column range.
                    unsafe {
                        *out.0.add((i0 + mi) * n + j) = if relu { acc.max(0.0) } else { acc };
                    }
                }
            }
        }
    }
}

/// Fused conv+ReLU epilogue with a snapshot-packed A: `out = A·B` with
/// the activation (`max(0.0)`, when `relu`) applied inside the
/// microkernel's C-tile store, eliminating one full pass over the
/// output. **Overwrites** `out` (no accumulate semantics).
/// Bit-identical to [`gemm_nn_mt`] into a zeroed buffer followed by
/// `relu::forward_vec` — same k-ascending chain from `0.0`, same
/// `max(0.0)` per element.
pub fn gemm_nn_fused_packed_mt(
    pa: &PackedA,
    n: usize,
    b: &[f32],
    out: &mut [f32],
    relu: bool,
    threads: usize,
) {
    let (m, k) = (pa.m, pa.k);
    assert_eq!(b.len(), k * n, "B must be k×n");
    assert_eq!(out.len(), m * n, "out must be m×n");
    if m == 0 || n == 0 {
        return;
    }
    crate::obs::count_gemm((m * k * n) as u64);
    let workers = plan_workers(threads, m * k * n, n);
    let ptr = SendPtr(out.as_mut_ptr());
    if workers <= 1 {
        gemm_nn_fused_range(m, k, n, &pa.data, b, ptr, relu, 0, n);
        return;
    }
    let ranges = col_ranges(n, workers);
    pool::run(ranges.len(), |wi| {
        let (lo, hi) = ranges[wi];
        gemm_nn_fused_range(m, k, n, &pa.data, b, ptr, relu, lo, hi);
    });
}

/// [`gemm_nn_fused_packed_mt`] packing A per call.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nn_fused_mt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    relu: bool,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A must be m×k");
    let pa = PackedA::pack(m, k, a);
    gemm_nn_fused_packed_mt(&pa, n, b, out, relu, threads);
}

/// `C (k×n) += Aᵀ · B` where `A` is `m×k` and `B` is `m×n`, row-major,
/// single-threaded. (Transposition is implicit: A is read row by row,
/// scattering into C rows, so every inner loop still runs over
/// contiguous memory.)
pub fn gemm_tn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    gemm_tn_mt(m, k, n, a, b, c, 1);
}

/// [`gemm_tn`] with the output columns sharded across up to `threads`
/// persistent-pool workers. Bit-identical to the single-threaded path:
/// each output element is one i-ascending (sample-ascending) FP-add
/// chain regardless of tiling or sharding.
pub fn gemm_tn_mt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A must be m×k");
    assert_eq!(b.len(), m * n, "B must be m×n");
    assert_eq!(c.len(), k * n, "C must be k×n");
    if k == 0 || n == 0 {
        return;
    }
    crate::obs::count_gemm((m * k * n) as u64);
    let workers = plan_workers(threads, m * k * n, n);
    let ptr = SendPtr(c.as_mut_ptr());
    if workers <= 1 {
        gemm_tn_range(m, k, n, a, b, ptr, 0, n);
        return;
    }
    let ranges = col_ranges(n, workers);
    pool::run(ranges.len(), |wi| {
        let (lo, hi) = ranges[wi];
        gemm_tn_range(m, k, n, a, b, ptr, lo, hi);
    });
}

/// One `KR_`×[`NR`] register tile of the TN kernel: C rows
/// `kk0..kk0+KR_`, accumulated over all m samples with i ascending —
/// the same per-output chain as the scalar scatter loop.
///
/// # Safety
/// The caller must own output columns `jj..jj+NR` of C rows
/// `kk0..kk0+KR_`.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn tn_tile<const KR_: usize>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: *mut f32,
    kk0: usize,
    jj: usize,
) {
    let mut acc = [[0.0f32; NR]; KR_];
    for (t, row) in acc.iter_mut().enumerate() {
        let crow = c.add((kk0 + t) * n + jj);
        for (u, v) in row.iter_mut().enumerate() {
            *v = *crow.add(u);
        }
    }
    for i in 0..m {
        let a_seg = &a[i * k + kk0..i * k + kk0 + KR_];
        let b_seg = &b[i * n + jj..i * n + jj + NR];
        for (t, row) in acc.iter_mut().enumerate() {
            let av = a_seg[t];
            for (v, &bv) in row.iter_mut().zip(b_seg) {
                *v += av * bv;
            }
        }
    }
    for (t, row) in acc.iter().enumerate() {
        let crow = c.add((kk0 + t) * n + jj);
        for (u, &v) in row.iter().enumerate() {
            *crow.add(u) = v;
        }
    }
}

/// Panel-blocked tiled TN kernel over output columns `lo..hi`: the
/// row-loop (reduction) order per output element never depends on
/// `(lo, hi)` or the tile shape.
#[allow(clippy::too_many_arguments)]
fn gemm_tn_range(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: SendPtr<f32>,
    lo: usize,
    hi: usize,
) {
    for j0 in (lo..hi).step_by(PANEL) {
        let j1 = (j0 + PANEL).min(hi);
        for kk0 in (0..k).step_by(MR) {
            let kr = MR.min(k - kk0);
            let mut jj = j0;
            // Safety: this worker is the only writer of columns lo..hi.
            unsafe {
                while jj + NR <= j1 {
                    match kr {
                        4 => tn_tile::<4>(m, k, n, a, b, c.0, kk0, jj),
                        3 => tn_tile::<3>(m, k, n, a, b, c.0, kk0, jj),
                        2 => tn_tile::<2>(m, k, n, a, b, c.0, kk0, jj),
                        _ => tn_tile::<1>(m, k, n, a, b, c.0, kk0, jj),
                    }
                    jj += NR;
                }
            }
            for j in jj..j1 {
                for t in 0..kr {
                    // Safety: as above — sole writer of this column range.
                    let cv = unsafe { &mut *c.0.add((kk0 + t) * n + j) };
                    let mut acc = *cv;
                    for i in 0..m {
                        acc += a[i * k + kk0 + t] * b[i * n + j];
                    }
                    *cv = acc;
                }
            }
        }
    }
}

/// The pre-tiling TN kernel, kept verbatim: scalar scatter rows that
/// **skip zero A operands**. Wins when A is a sparse post-ReLU
/// activation matrix and n is small (the dense weight gradient's
/// `Xᵀ (8192×B) · dY (B×10)`, where one skipped activation avoids a
/// whole 10-wide axpy). Bit-identical to [`gemm_tn_mt`] under the same
/// zero-initialized-C argument as [`gemm_nn_skipa_mt`].
pub fn gemm_tn_skipa_mt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A must be m×k");
    assert_eq!(b.len(), m * n, "B must be m×n");
    assert_eq!(c.len(), k * n, "C must be k×n");
    if k == 0 || n == 0 {
        return;
    }
    crate::obs::count_gemm((m * k * n) as u64);
    let workers = plan_workers(threads, m * k * n, n);
    let ptr = SendPtr(c.as_mut_ptr());
    if workers <= 1 {
        gemm_tn_skipa_range(k, n, a, b, ptr, 0, n);
        return;
    }
    let ranges = col_ranges(n, workers);
    pool::run(ranges.len(), |wi| {
        let (lo, hi) = ranges[wi];
        gemm_tn_skipa_range(k, n, a, b, ptr, lo, hi);
    });
}

/// The zero-skipping TN kernel over output columns `lo..hi`.
fn gemm_tn_skipa_range(
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: SendPtr<f32>,
    lo: usize,
    hi: usize,
) {
    for (a_row, b_row) in a.chunks_exact(k).zip(b.chunks_exact(n)) {
        for (kk, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            // Safety: this worker is the only writer of columns lo..hi.
            let c_row = unsafe { std::slice::from_raw_parts_mut(c.0.add(kk * n + lo), hi - lo) };
            for (cv, &bv) in c_row.iter_mut().zip(&b_row[lo..hi]) {
                *cv += av * bv;
            }
        }
    }
}

/// `C (m×n) += A · Bᵀ` where `A` is `m×kd` and `B` is `n×kd`, row-major,
/// single-threaded: every C element is a dot product of two contiguous
/// rows.
pub fn gemm_nt(m: usize, n: usize, kd: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    gemm_nt_mt(m, n, kd, a, b, c, 1);
}

/// [`gemm_nt`] with the output columns sharded across up to `threads`
/// persistent-pool workers. Bit-identical to the single-threaded path:
/// every output element runs exactly [`dot`]'s operation sequence,
/// whether computed alone or inside a 2×2 tile.
pub fn gemm_nt_mt(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    threads: usize,
) {
    assert_eq!(a.len(), m * kd, "A must be m×kd");
    assert_eq!(b.len(), n * kd, "B must be n×kd");
    assert_eq!(c.len(), m * n, "C must be m×n");
    if m == 0 || n == 0 {
        return;
    }
    crate::obs::count_gemm((m * kd * n) as u64);
    let workers = plan_workers(threads, m * kd.max(1) * n, n);
    let ptr = SendPtr(c.as_mut_ptr());
    if workers <= 1 {
        gemm_nt_range(m, n, kd, a, b, ptr, 0, n);
        return;
    }
    let ranges = col_ranges(n, workers);
    pool::run(ranges.len(), |wi| {
        let (lo, hi) = ranges[wi];
        gemm_nt_range(m, n, kd, a, b, ptr, lo, hi);
    });
}

/// A 2×2 NT register tile: four [`dot`]-structured reductions sharing
/// both operand streams (each A row is read once for two outputs, each
/// B row once for two outputs). Every output's FP operation sequence —
/// 8-accumulator chunks, scalar tail, fixed reduction tree — is exactly
/// [`dot`]'s, so the tile is bit-invisible.
///
/// # Safety
/// The caller must own output columns `j..j+2` of C rows `i0..i0+2`;
/// all four slices must have length `kd`.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn nt_tile_2x2(
    n: usize,
    kd: usize,
    a0: &[f32],
    a1: &[f32],
    b0: &[f32],
    b1: &[f32],
    c: *mut f32,
    i0: usize,
    j: usize,
) {
    let mut acc00 = [0.0f32; 8];
    let mut acc01 = [0.0f32; 8];
    let mut acc10 = [0.0f32; 8];
    let mut acc11 = [0.0f32; 8];
    let chunks = kd / 8 * 8;
    let mut o = 0;
    while o < chunks {
        for l in 0..8 {
            let x0 = a0[o + l];
            let x1 = a1[o + l];
            let y0 = b0[o + l];
            let y1 = b1[o + l];
            acc00[l] += x0 * y0;
            acc01[l] += x0 * y1;
            acc10[l] += x1 * y0;
            acc11[l] += x1 * y1;
        }
        o += 8;
    }
    let (mut t00, mut t01, mut t10, mut t11) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for l in chunks..kd {
        let x0 = a0[l];
        let x1 = a1[l];
        let y0 = b0[l];
        let y1 = b1[l];
        t00 += x0 * y0;
        t01 += x0 * y1;
        t10 += x1 * y0;
        t11 += x1 * y1;
    }
    *c.add(i0 * n + j) += dot_reduce(t00, &acc00);
    *c.add(i0 * n + j + 1) += dot_reduce(t01, &acc01);
    *c.add((i0 + 1) * n + j) += dot_reduce(t10, &acc10);
    *c.add((i0 + 1) * n + j + 1) += dot_reduce(t11, &acc11);
}

/// The tiled NT kernel over output columns `lo..hi`: 2×2 tiles of
/// [`dot`]-identical reductions, with row/column remainders falling
/// back to per-output [`dot`] calls.
#[allow(clippy::too_many_arguments)]
fn gemm_nt_range(
    m: usize,
    n: usize,
    kd: usize,
    a: &[f32],
    b: &[f32],
    c: SendPtr<f32>,
    lo: usize,
    hi: usize,
) {
    let mut i0 = 0;
    while i0 + 2 <= m {
        let a0 = &a[i0 * kd..(i0 + 1) * kd];
        let a1 = &a[(i0 + 1) * kd..(i0 + 2) * kd];
        let mut j = lo;
        // Safety: this worker is the only writer of columns lo..hi.
        unsafe {
            while j + 2 <= hi {
                let b0 = &b[j * kd..(j + 1) * kd];
                let b1 = &b[(j + 1) * kd..(j + 2) * kd];
                nt_tile_2x2(n, kd, a0, a1, b0, b1, c.0, i0, j);
                j += 2;
            }
            for jr in j..hi {
                let b_row = &b[jr * kd..(jr + 1) * kd];
                *c.0.add(i0 * n + jr) += dot(a0, b_row);
                *c.0.add((i0 + 1) * n + jr) += dot(a1, b_row);
            }
        }
        i0 += 2;
    }
    if i0 < m {
        let a_row = &a[i0 * kd..(i0 + 1) * kd];
        for jr in lo..hi {
            let b_row = &b[jr * kd..(jr + 1) * kd];
            // Safety: as above — sole writer of this column range.
            unsafe {
                *c.0.add(i0 * n + jr) += dot(a_row, b_row);
            }
        }
    }
}

/// [`dot`]'s fixed reduction tree over its 8 accumulators plus the
/// scalar tail — factored out so the NT tile provably shares it.
#[inline(always)]
fn dot_reduce(tail: f32, acc: &[f32; 8]) -> f32 {
    tail + ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))
}

/// Unrolled dot product: 8 independent accumulators break the sequential
/// FP-add dependency chain so the loop pipelines/vectorizes.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let a8 = a.chunks_exact(8);
    let b8 = b.chunks_exact(8);
    let ra = a8.remainder();
    let rb = b8.remainder();
    let mut acc = [0.0f32; 8];
    for (ca, cb) in a8.zip(b8) {
        for l in 0..8 {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    dot_reduce(tail, &acc)
}

/// Scalar single-threaded NN reference (`C += A·B`, one k-ascending
/// chain per output). Pins the microkernels in the parity tests.
pub fn gemm_nn_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = c[i * n + j];
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

/// Scalar single-threaded TN reference (`C (k×n) += Aᵀ·B`, one
/// i-ascending chain per output).
pub fn gemm_tn_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    for kk in 0..k {
        for j in 0..n {
            let mut acc = c[kk * n + j];
            for i in 0..m {
                acc += a[i * k + kk] * b[i * n + j];
            }
            c[kk * n + j] = acc;
        }
    }
}

/// Scalar single-threaded NT reference (`C (m×n) += A·Bᵀ`, one [`dot`]
/// per output).
pub fn gemm_nt_ref(m: usize, n: usize, kd: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * kd);
    assert_eq!(b.len(), n * kd);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] += dot(&a[i * kd..(i + 1) * kd], &b[j * kd..(j + 1) * kd]);
        }
    }
}

/// Pack a CHW input into the `(Cin·Kh·Kw) × (Oh·Ow)` column matrix for a
/// `Kh×Kw` convolution. Out-of-image taps (padding) stay zero. Returns
/// the matrix and the output spatial size.
pub fn im2col(
    x: &Tensor<f32>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> (Vec<f32>, usize, usize) {
    let [cin, h, w]: [usize; 3] = x.shape().dims().try_into().expect("x must be CHW");
    im2col_batch(x.data(), 1, cin, h, w, kh, kw, stride, pad, 1)
}

/// Batched [`im2col`]: `x` is a channel-major packed batch — a row-major
/// `(Cin, B·H·W)` matrix whose row `c` is image 0's plane, then image
/// 1's, … (for `B = 1` this is plain CHW). Packs all images into one
/// `(Cin·Kh·Kw) × (B·Oh·Ow)` column matrix with image-major columns
/// (image `b` owns columns `b·Oh·Ow ..`). Images are sharded across up
/// to `threads` pool workers; each image's columns are disjoint, so
/// the result is bit-identical at any thread count. Generic over the
/// element (pure data movement; out-of-image taps stay `T::default()`)
/// so the f32 and Q4.12 engines share one packing definition.
#[allow(clippy::too_many_arguments)]
pub fn im2col_batch<T: Copy + Default>(
    x: &[T],
    batch: usize,
    cin: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    threads: usize,
) -> (Vec<T>, usize, usize) {
    let mut cols = Vec::new();
    let (oh, ow) = im2col_batch_into(x, batch, cin, h, w, kh, kw, stride, pad, threads, &mut cols);
    (cols, oh, ow)
}

/// True when a conv's column matrix *is* its channel-major packed input
/// (1×1 kernel, stride 1, no padding) — the im2col copy can be elided
/// bit-exactly, because every column is the single in-image tap at the
/// same spatial index.
pub fn im2col_elidable(kh: usize, kw: usize, stride: usize, pad: usize) -> bool {
    kh == 1 && kw == 1 && stride == 1 && pad == 0
}

/// [`im2col_batch`] into a caller-provided buffer, so serve batches and
/// train steps reuse one allocation instead of churning a multi-MB
/// column matrix per call. The buffer is cleared and zero-filled to the
/// exact size first (out-of-image taps must read `T::default()`), then
/// packed identically to [`im2col_batch`].
#[allow(clippy::too_many_arguments)]
pub fn im2col_batch_into<T: Copy + Default>(
    x: &[T],
    batch: usize,
    cin: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    threads: usize,
    cols: &mut Vec<T>,
) -> (usize, usize) {
    assert!(batch > 0, "empty batch");
    assert_eq!(x.len(), cin * batch * h * w, "packed input size");
    let oh = out_size(h, kh, stride, pad);
    let ow = out_size(w, kw, stride, pad);
    let n = oh * ow;
    let bn = batch * n;
    cols.clear();
    cols.resize(cin * kh * kw * bn, T::default());
    let workers = plan_workers(threads, cols.len(), batch);
    let ptr = SendPtr(cols.as_mut_ptr());
    let pack_images = |b0: usize, b1: usize| {
        for bi in b0..b1 {
            let mut row = 0;
            for ic in 0..cin {
                let plane = &x[(ic * batch + bi) * h * w..(ic * batch + bi + 1) * h * w];
                for ky in 0..kh {
                    for kx in 0..kw {
                        // Safety: image bi's columns are written only by
                        // the worker that owns bi.
                        let dest = unsafe {
                            std::slice::from_raw_parts_mut(ptr.0.add(row * bn + bi * n), n)
                        };
                        for oy in 0..oh {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            let src = &plane[iy as usize * w..iy as usize * w + w];
                            let drow = &mut dest[oy * ow..(oy + 1) * ow];
                            for ox in 0..ow {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if ix >= 0 && ix < w as isize {
                                    drow[ox] = src[ix as usize];
                                }
                            }
                        }
                        row += 1;
                    }
                }
            }
        }
    };
    if workers <= 1 {
        pack_images(0, batch);
    } else {
        let ranges = col_ranges(batch, workers);
        pool::run(ranges.len(), |wi| {
            let (b0, b1) = ranges[wi];
            pack_images(b0, b1);
        });
    }
    (oh, ow)
}

/// Scatter-add a `(Cin·Kh·Kw) × (B·Oh·Ow)` column-gradient matrix back
/// into a channel-major packed `(Cin, B·H·W)` input gradient (the
/// adjoint of [`im2col_batch`]). Images are sharded across workers; each
/// image's accumulation runs on exactly one worker in a fixed order, so
/// the result is bit-identical at any thread count.
#[allow(clippy::too_many_arguments)]
fn col2im_batch(
    dcols: &[f32],
    batch: usize,
    cin: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    threads: usize,
) -> Vec<f32> {
    let n = oh * ow;
    let bn = batch * n;
    assert_eq!(dcols.len(), cin * kh * kw * bn, "column-gradient size");
    let mut dx = vec![0.0f32; cin * batch * h * w];
    let workers = plan_workers(threads, dcols.len(), batch);
    let ptr = SendPtr(dx.as_mut_ptr());
    let scatter_images = |b0: usize, b1: usize| {
        for bi in b0..b1 {
            let mut row = 0;
            for ic in 0..cin {
                // Safety: image bi's plane is written only by the worker
                // that owns bi.
                let plane = unsafe {
                    std::slice::from_raw_parts_mut(ptr.0.add((ic * batch + bi) * h * w), h * w)
                };
                for ky in 0..kh {
                    for kx in 0..kw {
                        let src = &dcols[row * bn + bi * n..row * bn + bi * n + n];
                        for oy in 0..oh {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            let drow = &mut plane[iy as usize * w..iy as usize * w + w];
                            let srow = &src[oy * ow..(oy + 1) * ow];
                            for ox in 0..ow {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if ix >= 0 && ix < w as isize {
                                    drow[ix as usize] += srow[ox];
                                }
                            }
                        }
                        row += 1;
                    }
                }
            }
        }
    };
    if workers <= 1 {
        scatter_images(0, batch);
    } else {
        let ranges = col_ranges(batch, workers);
        pool::run(ranges.len(), |wi| {
            let (b0, b1) = ranges[wi];
            scatter_images(b0, b1);
        });
    }
    dx
}

/// Forward convolution (paper Eq. 1) via im2col + GEMM. Drop-in
/// replacement for [`super::conv::forward`].
pub fn forward(x: &Tensor<f32>, kernel: &Tensor<f32>, stride: usize, pad: usize) -> Tensor<f32> {
    let [cin, h, w]: [usize; 3] = x.shape().dims().try_into().expect("x must be CHW");
    let kd = kernel.shape().dims();
    let (cout, kcin, kh, kw) = (kd[0], kd[1], kd[2], kd[3]);
    assert_eq!(cin, kcin, "channel mismatch: x {cin} vs kernel {kcin}");
    if im2col_elidable(kh, kw, stride, pad) {
        // The CHW input *is* the (Cin × H·W) column matrix — skip the copy.
        let out = conv_forward_batch(x.data(), kernel, h * w, 1);
        return Tensor::from_vec(Shape::d3(cout, h, w), out);
    }
    let (cols, oh, ow) = im2col(x, kh, kw, stride, pad);
    let out = conv_forward_batch(&cols, kernel, oh * ow, 1);
    Tensor::from_vec(Shape::d3(cout, oh, ow), out)
}

/// Batched forward conv over an already-packed column matrix: one
/// `Cout × (B·Oh·Ow)` GEMM. Returns the channel-major packed output.
pub fn conv_forward_batch(
    cols: &[f32],
    kernel: &Tensor<f32>,
    bn: usize,
    threads: usize,
) -> Vec<f32> {
    let kd = kernel.shape().dims();
    let (cout, kdim) = (kd[0], kd[1] * kd[2] * kd[3]);
    let mut out = vec![0.0f32; cout * bn];
    gemm_nn_mt(cout, kdim, bn, kernel.data(), cols, &mut out, threads);
    out
}

/// [`conv_forward_batch`] with a snapshot-packed kernel and the fused
/// epilogue, writing into a caller-provided scratch buffer: `out =
/// relu?(K·cols)` in one pass. Bit-identical to [`conv_forward_batch`]
/// followed by `relu::forward_vec` (see [`gemm_nn_fused_packed_mt`]).
pub fn conv_forward_batch_packed_into(
    cols: &[f32],
    pk: &PackedA,
    bn: usize,
    relu: bool,
    out: &mut Vec<f32>,
    threads: usize,
) {
    out.clear();
    out.resize(pk.m() * bn, 0.0);
    gemm_nn_fused_packed_mt(pk, bn, cols, out, relu, threads);
}

/// Gradient w.r.t. the input (paper Eq. 2) via GEMM + col2im. Drop-in
/// replacement for [`super::conv::input_grad`].
pub fn input_grad(
    dy: &Tensor<f32>,
    kernel: &Tensor<f32>,
    x_shape: &Shape,
    stride: usize,
    pad: usize,
) -> Tensor<f32> {
    let [cin, h, w]: [usize; 3] = x_shape.dims().try_into().expect("x_shape must be CHW");
    let kd = kernel.shape().dims();
    let (cout, kcin, kh, kw) = (kd[0], kd[1], kd[2], kd[3]);
    assert_eq!(cin, kcin);
    let dyd = dy.shape().dims();
    assert_eq!(dyd[0], cout, "dy channels");
    let (oh, ow) = (dyd[1], dyd[2]);
    debug_assert_eq!(oh, out_size(h, kh, stride, pad));
    debug_assert_eq!(ow, out_size(w, kw, stride, pad));
    let dx = conv_input_grad_batch(dy.data(), kernel, 1, h, w, stride, pad, oh, ow, 1);
    Tensor::from_vec(x_shape.clone(), dx)
}

/// Batched input gradient: `dy` is the channel-major packed output
/// gradient `(Cout, B·Oh·Ow)`; the result is the channel-major packed
/// input gradient `(Cin, B·H·W)`.
#[allow(clippy::too_many_arguments)]
pub fn conv_input_grad_batch(
    dy: &[f32],
    kernel: &Tensor<f32>,
    batch: usize,
    h: usize,
    w: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    threads: usize,
) -> Vec<f32> {
    let kd = kernel.shape().dims();
    let (cout, cin, kh, kw) = (kd[0], kd[1], kd[2], kd[3]);
    let bn = batch * oh * ow;
    assert_eq!(dy.len(), cout * bn, "dy size");
    let kdim = cin * kh * kw;
    let mut dcols = vec![0.0f32; kdim * bn];
    gemm_tn_mt(cout, kdim, bn, kernel.data(), dy, &mut dcols, threads);
    if im2col_elidable(kh, kw, stride, pad) {
        // The column gradient *is* the packed input gradient (every
        // column owns exactly one in-image tap) — skip the scatter.
        return dcols;
    }
    col2im_batch(&dcols, batch, cin, h, w, kh, kw, stride, pad, oh, ow, threads)
}

/// Gradient w.r.t. the kernel (paper Eq. 3) via im2col + GEMM. Drop-in
/// replacement for [`super::conv::kernel_grad`].
pub fn kernel_grad(
    dy: &Tensor<f32>,
    x: &Tensor<f32>,
    kernel_shape: &Shape,
    stride: usize,
    pad: usize,
) -> Tensor<f32> {
    let [cin, h, w]: [usize; 3] = x.shape().dims().try_into().expect("x must be CHW");
    let kd = kernel_shape.dims();
    let (cout, kcin, kh, kw) = (kd[0], kd[1], kd[2], kd[3]);
    assert_eq!(cin, kcin);
    let (held, oh, ow);
    let cols: &[f32] = if im2col_elidable(kh, kw, stride, pad) {
        // The CHW input *is* the column matrix — borrow it directly.
        (oh, ow) = (h, w);
        x.data()
    } else {
        (held, oh, ow) = im2col(x, kh, kw, stride, pad);
        &held
    };
    let dyd = dy.shape().dims();
    assert_eq!(dyd[0], cout);
    assert_eq!((dyd[1], dyd[2]), (oh, ow), "dy geometry vs conv geometry");
    conv_kernel_grad_batch(dy.data(), cols, kernel_shape, oh * ow, 1)
}

/// Batched kernel gradient over an already-packed column matrix:
/// `dK (Cout×KD) = dY (Cout×B·N) · colsᵀ`. The gradient is *summed*
/// over the batch (the caller scales by `1/B` for mean-gradient SGD).
pub fn conv_kernel_grad_batch(
    dy: &[f32],
    cols: &[f32],
    kernel_shape: &Shape,
    bn: usize,
    threads: usize,
) -> Tensor<f32> {
    let kd = kernel_shape.dims();
    let (cout, kdim) = (kd[0], kd[1] * kd[2] * kd[3]);
    assert_eq!(dy.len(), cout * bn, "dy size");
    assert_eq!(cols.len(), kdim * bn, "cols size");
    let mut dk = vec![0.0f32; cout * kdim];
    gemm_nt_mt(cout, kdim, bn, dy, cols, &mut dk, threads);
    Tensor::from_vec(kernel_shape.clone(), dk)
}

/// Dense forward (Eq. 4) through the GEMM core: `y (1×Nout) = x (1×Nin) ·
/// W (Nin×Nout)`.
pub fn dense_forward(x: &[f32], w: &Tensor<f32>) -> Vec<f32> {
    dense_forward_batch(x, w, 1, 1)
}

/// Batched dense forward: `Y (B×Nout) = X (B×Nin) · W (Nin×Nout)`, with
/// `X` in sample-major rows (see [`packed_to_rows`]). X is a post-ReLU
/// activation matrix (~half zeros at the paper geometry) and `Nout` is
/// tiny, so this is the one forward GEMM where the zero-skipping kernel
/// beats the register-tiled one — pinned by the `gemm` micro-rung.
pub fn dense_forward_batch(x: &[f32], w: &Tensor<f32>, batch: usize, threads: usize) -> Vec<f32> {
    let [n_in, n_out]: [usize; 2] = w.shape().dims().try_into().expect("w must be 2D");
    assert_eq!(x.len(), batch * n_in, "input length {} vs {batch}×{n_in}", x.len());
    let mut y = vec![0.0f32; batch * n_out];
    gemm_nn_skipa_mt(batch, n_in, n_out, x, w.data(), &mut y, threads);
    y
}

/// Dense input gradient (Eq. 5): `dX (Nin) = W (Nin×Nout) · dY (Nout)` —
/// one contiguous-row dot per input element.
pub fn dense_input_grad(dy: &[f32], w: &Tensor<f32>) -> Vec<f32> {
    dense_input_grad_batch(dy, w, 1, 1)
}

/// Batched dense input gradient: `dX (B×Nin) = dY (B×Nout) · Wᵀ`.
pub fn dense_input_grad_batch(
    dy: &[f32],
    w: &Tensor<f32>,
    batch: usize,
    threads: usize,
) -> Vec<f32> {
    let [n_in, n_out]: [usize; 2] = w.shape().dims().try_into().expect("w must be 2D");
    assert_eq!(dy.len(), batch * n_out);
    let mut dx = vec![0.0f32; batch * n_in];
    gemm_nt_mt(batch, n_in, n_out, dy, w.data(), &mut dx, threads);
    dx
}

/// Dense weight gradient (Eq. 6): rank-1 outer product `dW = x ⊗ dY`,
/// written row-at-a-time (axpy form, skipping post-ReLU zeros).
pub fn dense_weight_grad(dy: &[f32], x: &[f32]) -> Tensor<f32> {
    dense_weight_grad_batch(dy, x, 1, x.len(), dy.len(), 1)
}

/// Batched dense weight gradient: `dW (Nin×Nout) = Xᵀ (Nin×B) · dY
/// (B×Nout)` — the rank-B generalization of the outer product, *summed*
/// over the batch (the caller scales by `1/B`).
pub fn dense_weight_grad_batch(
    dy: &[f32],
    x: &[f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
    threads: usize,
) -> Tensor<f32> {
    assert_eq!(x.len(), batch * n_in, "x size");
    assert_eq!(dy.len(), batch * n_out, "dy size");
    let mut dw = vec![0.0f32; n_in * n_out];
    // A = Xᵀ is the post-ReLU activation matrix (~half zeros) and n_out
    // is tiny — the zero-skipping kernel's territory, like the forward.
    gemm_tn_skipa_mt(batch, n_in, n_out, x, dy, &mut dw, threads);
    Tensor::from_vec(Shape::d2(n_in, n_out), dw)
}

/// Pack `B` same-shape CHW images into the channel-major batch layout —
/// a row-major `(C, B·H·W)` matrix whose row `c` holds image 0's plane,
/// then image 1's, … Generic over the element so the f32 and Q4.12
/// (`fixed::Fx`) engines share one layout definition.
pub fn pack_batch<T: Copy + Default>(xs: &[&Tensor<T>]) -> Vec<T> {
    assert!(!xs.is_empty(), "empty batch");
    let shape = xs[0].shape();
    let [c, h, w]: [usize; 3] = shape.dims().try_into().expect("samples must be CHW");
    let (b, n) = (xs.len(), h * w);
    let mut out = vec![T::default(); c * b * n];
    for (bi, x) in xs.iter().enumerate() {
        assert_eq!(x.shape(), shape, "batch samples must share a shape");
        let xd = x.data();
        for ci in 0..c {
            let dst = (ci * b + bi) * n;
            out[dst..dst + n].copy_from_slice(&xd[ci * n..(ci + 1) * n]);
        }
    }
    out
}

/// Channel-major packed `(C, B·N)` → sample-major rows `(B, C·N)`: row
/// `b` is image `b`'s flattened CHW activation, ready for the dense
/// GEMM.
pub fn packed_to_rows<T: Copy + Default>(
    packed: &[T],
    channels: usize,
    batch: usize,
    n: usize,
) -> Vec<T> {
    assert_eq!(packed.len(), channels * batch * n);
    let mut rows = vec![T::default(); batch * channels * n];
    for c in 0..channels {
        for b in 0..batch {
            let src = (c * batch + b) * n;
            let dst = (b * channels + c) * n;
            rows[dst..dst + n].copy_from_slice(&packed[src..src + n]);
        }
    }
    rows
}

/// Sample-major rows `(B, C·N)` → channel-major packed `(C, B·N)` — the
/// inverse of [`packed_to_rows`] (used on the dense layer's input
/// gradient before it re-enters the conv stack). The inverse block
/// transpose is the same transpose with the axis roles swapped.
pub fn rows_to_packed<T: Copy + Default>(
    rows: &[T],
    channels: usize,
    batch: usize,
    n: usize,
) -> Vec<T> {
    packed_to_rows(rows, batch, channels, n)
}

/// Flatten per-sample CHW tensors straight into the sample-major row
/// layout `(B, C·N)` — the dense layer's input when the activations are
/// already split per sample (latent replay's dense-only cut feeds stored
/// a2 activations here without a pack/unpack round trip).
pub fn rows_from_samples<T: Copy>(xs: &[&Tensor<T>]) -> Vec<T> {
    assert!(!xs.is_empty(), "empty batch");
    let shape = xs[0].shape();
    let mut out = Vec::with_capacity(xs.len() * shape.numel());
    for x in xs {
        assert_eq!(x.shape(), shape, "batch samples must share a shape");
        out.extend_from_slice(x.data());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{conv, dense};
    use crate::util::proptest::assert_close;
    use crate::util::rng::Pcg32;

    fn rand_tensor(rng: &mut Pcg32, shape: Shape) -> Tensor<f32> {
        let n = shape.numel();
        Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect()
    }

    #[test]
    fn gemm_nn_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        gemm_nn(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn gemm_tn_is_a_transpose_times_b() {
        // Aᵀ·B with A = [1 2; 3 4] (2×2), B = [5 6; 7 8]:
        // Aᵀ = [1 3; 2 4] → [1·5+3·7, 1·6+3·8; 2·5+4·7, 2·6+4·8]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        gemm_tn(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, [26.0, 30.0, 38.0, 44.0]);
    }

    #[test]
    fn gemm_nt_is_a_times_b_transpose() {
        // A·Bᵀ with A = [1 2; 3 4], B = [5 6; 7 8]:
        // [1·5+2·6, 1·7+2·8; 3·5+4·6, 3·7+4·8]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        gemm_nt(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, [17.0, 23.0, 39.0, 53.0]);
    }

    #[test]
    fn gemm_panels_cover_wide_matrices() {
        // n > PANEL exercises the panel loop. C = A·B with A = ones(1×2),
        // B = ones(2×n) → every C element is 2.
        let n = PANEL * 2 + 37;
        let a = vec![1.0f32; 2];
        let b = vec![1.0f32; 2 * n];
        let mut c = vec![0.0f32; n];
        gemm_nn(1, 2, n, &a, &b, &mut c);
        assert!(c.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn mt_gemms_bit_identical_to_single_thread() {
        // Problem sizes above MT_MIN_MACS so the sharded path actually
        // engages; column sharding must not change a single bit.
        let mut rng = Pcg32::seeded(31);
        let (m, k, n) = (8, 32, 512); // 131072 MACs
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        for threads in [2, 3, 5] {
            let mut c1 = vec![0.0f32; m * n];
            let mut cn = vec![0.0f32; m * n];
            gemm_nn_mt(m, k, n, &a, &b, &mut c1, 1);
            gemm_nn_mt(m, k, n, &a, &b, &mut cn, threads);
            assert_eq!(c1, cn, "gemm_nn threads={threads}");
        }

        let (m, k, n) = (32, 16, 256); // 131072 MACs, C = 16×256
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, m * n);
        for threads in [2, 4] {
            let mut c1 = vec![0.0f32; k * n];
            let mut cn = vec![0.0f32; k * n];
            gemm_tn_mt(m, k, n, &a, &b, &mut c1, 1);
            gemm_tn_mt(m, k, n, &a, &b, &mut cn, threads);
            assert_eq!(c1, cn, "gemm_tn threads={threads}");
        }

        let (m, n, kd) = (16, 64, 128); // 131072 MACs
        let a = rand_vec(&mut rng, m * kd);
        let b = rand_vec(&mut rng, n * kd);
        for threads in [2, 7] {
            let mut c1 = vec![0.0f32; m * n];
            let mut cn = vec![0.0f32; m * n];
            gemm_nt_mt(m, n, kd, &a, &b, &mut c1, 1);
            gemm_nt_mt(m, n, kd, &a, &b, &mut cn, threads);
            assert_eq!(c1, cn, "gemm_nt threads={threads}");
        }
    }

    #[test]
    fn mt_threshold_keeps_tiny_problems_single_threaded() {
        // plan_workers/col_ranges unit properties live with the helpers
        // in `util::pool`; here only the GEMM-level consequence:
        // an oversubscribed tiny GEMM still computes correctly.
        let a = [1.0, 2.0];
        let b = [3.0, 4.0];
        let mut c = [0.0f32; 1];
        gemm_nt_mt(1, 1, 2, &a, &b, &mut c, 16);
        assert_eq!(c, [11.0]);
    }

    #[test]
    fn dot_matches_reference_on_odd_lengths() {
        let mut rng = Pcg32::seeded(5);
        for len in [0usize, 1, 7, 8, 9, 64, 100] {
            let a: Vec<f32> = (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let expect: f64 =
                a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            assert!((dot(&a, &b) as f64 - expect).abs() < 1e-4, "len {len}");
        }
    }

    #[test]
    fn tiled_kernels_bit_identical_to_scalar_refs_and_variants() {
        // Remainder-shape sweep incl. forced zeros: the tiled kernels,
        // the zero-skipping kernels, the packed path, and the fused
        // epilogue must all agree with the scalar references bit for
        // bit. (The full randomized grid lives in
        // tests/microkernel_parity.rs.)
        let mut rng = Pcg32::seeded(41);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 9, 17), (8, 27, 33)] {
            let mut a = rand_vec(&mut rng, m * k);
            for v in a.iter_mut() {
                if rng.next_u32() % 3 == 0 {
                    *v = 0.0;
                }
            }
            let b = rand_vec(&mut rng, k * n);
            let mut c_ref = vec![0.0f32; m * n];
            gemm_nn_ref(m, k, n, &a, &b, &mut c_ref);
            let mut c = vec![0.0f32; m * n];
            gemm_nn_mt(m, k, n, &a, &b, &mut c, 1);
            assert_eq!(c, c_ref, "nn tiled {m}x{k}x{n}");
            let mut c = vec![0.0f32; m * n];
            gemm_nn_skipa_mt(m, k, n, &a, &b, &mut c, 1);
            assert_eq!(c, c_ref, "nn skipa {m}x{k}x{n}");
            let pa = PackedA::pack(m, k, &a);
            assert!(pa.matches(m, k, &a));
            let mut c = vec![0.0f32; m * n];
            gemm_nn_packed_mt(&pa, n, &b, &mut c, 1);
            assert_eq!(c, c_ref, "nn packed {m}x{k}x{n}");
            for relu in [false, true] {
                let mut fused = vec![f32::NAN; m * n];
                gemm_nn_fused_mt(m, k, n, &a, &b, &mut fused, relu, 1);
                let unfused: Vec<f32> =
                    c_ref.iter().map(|&v| if relu { v.max(0.0) } else { v }).collect();
                assert_eq!(fused, unfused, "nn fused {m}x{k}x{n} relu={relu}");
            }

            // TN: A is m×k, B is m×n, C is k×n.
            let b2 = rand_vec(&mut rng, m * n);
            let mut c_ref = vec![0.0f32; k * n];
            gemm_tn_ref(m, k, n, &a, &b2, &mut c_ref);
            let mut c = vec![0.0f32; k * n];
            gemm_tn_mt(m, k, n, &a, &b2, &mut c, 1);
            assert_eq!(c, c_ref, "tn tiled {m}x{k}x{n}");
            let mut c = vec![0.0f32; k * n];
            gemm_tn_skipa_mt(m, k, n, &a, &b2, &mut c, 1);
            assert_eq!(c, c_ref, "tn skipa {m}x{k}x{n}");

            // NT: A is m×kd, B is n×kd with kd = k.
            let b3 = rand_vec(&mut rng, n * k);
            let mut c_ref = vec![0.0f32; m * n];
            gemm_nt_ref(m, n, k, &a, &b3, &mut c_ref);
            let mut c = vec![0.0f32; m * n];
            gemm_nt_mt(m, n, k, &a, &b3, &mut c, 1);
            assert_eq!(c, c_ref, "nt tiled {m}x{k}x{n}");
        }
    }

    #[test]
    fn stale_pack_is_detected() {
        let mut rng = Pcg32::seeded(53);
        let a = rand_vec(&mut rng, 6 * 7);
        let pa = PackedA::pack(6, 7, &a);
        assert!(pa.matches(6, 7, &a));
        let mut stale = a.clone();
        stale[13] += 1.0;
        assert!(!pa.matches(6, 7, &stale));
        assert!(!pa.matches(7, 6, &a));
    }

    #[test]
    fn im2col_into_reuses_buffer_and_matches_fresh() {
        let mut rng = Pcg32::seeded(47);
        let shape = Shape::d3(2, 6, 6);
        let xs: Vec<Tensor<f32>> = (0..2).map(|_| rand_tensor(&mut rng, shape.clone())).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        let packed = pack_batch(&refs);
        let (fresh, oh, ow) = im2col_batch(&packed, 2, 2, 6, 6, 3, 3, 1, 1, 1);
        // A dirty, wrong-sized buffer must come out identical: padding
        // taps must be re-zeroed, not inherited.
        let mut buf = vec![7.0f32; 10];
        let (oh2, ow2) = im2col_batch_into(&packed, 2, 2, 6, 6, 3, 3, 1, 1, 1, &mut buf);
        assert_eq!((oh, ow), (oh2, ow2));
        assert_eq!(fresh, buf);
        // Second fill reuses the allocation.
        let cap = buf.capacity();
        im2col_batch_into(&packed, 2, 2, 6, 6, 3, 3, 1, 1, 1, &mut buf);
        assert_eq!(fresh, buf);
        assert_eq!(cap, buf.capacity());
    }

    #[test]
    fn one_by_one_conv_elision_is_bit_exact() {
        // 1×1/stride-1/pad-0: the packed activation IS the column
        // matrix; the elided paths must match the explicit im2col /
        // col2im paths exactly.
        let mut rng = Pcg32::seeded(43);
        let x = rand_tensor(&mut rng, Shape::d3(3, 6, 5));
        let k = rand_tensor(&mut rng, Shape::d4(4, 3, 1, 1));
        let (cols, oh, ow) = im2col(&x, 1, 1, 1, 0);
        assert_eq!(&cols, x.data(), "elision precondition: cols == x");
        let y = forward(&x, &k, 1, 0);
        let out = conv_forward_batch(&cols, &k, oh * ow, 1);
        assert_eq!(y.data(), &out[..], "elided forward");

        let dy = rand_tensor(&mut rng, Shape::d3(4, 6, 5));
        let dx = input_grad(&dy, &k, x.shape(), 1, 0);
        let mut dcols = vec![0.0f32; 3 * oh * ow];
        gemm_tn_mt(4, 3, oh * ow, k.data(), dy.data(), &mut dcols, 1);
        let back = col2im_batch(&dcols, 1, 3, 6, 5, 1, 1, 1, 0, oh, ow, 1);
        assert_eq!(dx.data(), &back[..], "elided input_grad");

        let dk = kernel_grad(&dy, &x, k.shape(), 1, 0);
        let dk2 = conv_kernel_grad_batch(dy.data(), &cols, k.shape(), oh * ow, 1);
        assert_eq!(dk.data(), dk2.data(), "elided kernel_grad");
    }

    #[test]
    fn identity_kernel_passthrough() {
        let mut rng = Pcg32::seeded(1);
        let x = rand_tensor(&mut rng, Shape::d3(1, 5, 5));
        let k = Tensor::from_vec(Shape::d4(1, 1, 1, 1), vec![1.0]);
        let y = forward(&x, &k, 1, 0);
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn known_3x3_convolution() {
        let x = Tensor::full(Shape::d3(1, 3, 3), 1.0f32);
        let k = Tensor::full(Shape::d4(1, 1, 3, 3), 1.0f32);
        let y = forward(&x, &k, 1, 1);
        assert_eq!(y.data(), &[4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn stride_two_matches_naive() {
        let mut rng = Pcg32::seeded(9);
        let x = rand_tensor(&mut rng, Shape::d3(2, 7, 7));
        let k = rand_tensor(&mut rng, Shape::d4(3, 2, 3, 3));
        let fast = forward(&x, &k, 2, 1);
        let naive = conv::forward(&x, &k, 2, 1);
        assert_eq!(fast.shape(), naive.shape());
        assert_close(fast.data(), naive.data(), 1e-5, "stride-2 forward");
    }

    #[test]
    fn paper_geometry_matches_naive_all_three_ops() {
        let mut rng = Pcg32::seeded(2);
        let x = rand_tensor(&mut rng, Shape::d3(8, 32, 32));
        let k = rand_tensor(&mut rng, Shape::d4(8, 8, 3, 3));
        let y_fast = forward(&x, &k, 1, 1);
        let y_naive = conv::forward(&x, &k, 1, 1);
        assert_close(y_fast.data(), y_naive.data(), 1e-4, "forward");

        let dy = rand_tensor(&mut rng, y_naive.shape().clone());
        let dx_fast = input_grad(&dy, &k, x.shape(), 1, 1);
        let dx_naive = conv::input_grad(&dy, &k, x.shape(), 1, 1);
        assert_close(dx_fast.data(), dx_naive.data(), 1e-4, "input_grad");

        let dk_fast = kernel_grad(&dy, &x, k.shape(), 1, 1);
        let dk_naive = conv::kernel_grad(&dy, &x, k.shape(), 1, 1);
        assert_close(dk_fast.data(), dk_naive.data(), 1e-4, "kernel_grad");
    }

    #[test]
    fn dense_ops_match_naive() {
        let mut rng = Pcg32::seeded(3);
        let (n_in, n_out) = (64, 10);
        let x: Vec<f32> = (0..n_in).map(|_| rng.range_f32(-1.0, 1.0).max(0.0)).collect();
        let w = rand_tensor(&mut rng, Shape::d2(n_in, n_out));
        let dy: Vec<f32> = (0..n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();

        assert_close(&dense_forward(&x, &w), &dense::forward(&x, &w), 1e-5, "dense fwd");
        assert_close(
            &dense_input_grad(&dy, &w),
            &dense::input_grad(&dy, &w),
            1e-5,
            "dense dX",
        );
        assert_close(
            dense_weight_grad(&dy, &x).data(),
            dense::weight_grad(&dy, &x).data(),
            1e-5,
            "dense dW",
        );
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), c> == <x, col2im(c)> — the defining adjoint
        // identity that makes input_grad the exact transpose of forward.
        let mut rng = Pcg32::seeded(11);
        let x = rand_tensor(&mut rng, Shape::d3(2, 5, 5));
        let (cols, oh, ow) = im2col(&x, 3, 3, 1, 1);
        let c: Vec<f32> = (0..cols.len()).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let lhs: f64 = cols.iter().zip(&c).map(|(&a, &b)| a as f64 * b as f64).sum();
        let back = col2im_batch(&c, 1, 2, 5, 5, 3, 3, 1, 1, oh, ow, 1);
        let rhs: f64 = x.data().iter().zip(&back).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3, "adjoint identity violated: {lhs} vs {rhs}");
    }

    #[test]
    fn pack_batch_and_row_transposes_roundtrip() {
        let mut rng = Pcg32::seeded(13);
        let shape = Shape::d3(3, 4, 5);
        let xs: Vec<Tensor<f32>> = (0..4).map(|_| rand_tensor(&mut rng, shape.clone())).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        let packed = pack_batch(&refs);
        let n = 4 * 5;
        // Image b, channel c plane sits at row c, columns b·N..(b+1)·N.
        for (bi, x) in xs.iter().enumerate() {
            for c in 0..3 {
                assert_eq!(
                    &packed[(c * 4 + bi) * n..(c * 4 + bi + 1) * n],
                    &x.data()[c * n..(c + 1) * n],
                    "image {bi} channel {c}"
                );
            }
        }
        // packed → rows is per-sample flattened CHW; rows → packed inverts.
        let rows = packed_to_rows(&packed, 3, 4, n);
        for (bi, x) in xs.iter().enumerate() {
            assert_eq!(&rows[bi * 3 * n..(bi + 1) * 3 * n], x.data(), "row {bi}");
        }
        assert_eq!(rows_to_packed(&rows, 3, 4, n), packed);
    }

    #[test]
    fn im2col_batch_matches_per_image() {
        let mut rng = Pcg32::seeded(17);
        let shape = Shape::d3(2, 6, 6);
        let xs: Vec<Tensor<f32>> = (0..3).map(|_| rand_tensor(&mut rng, shape.clone())).collect();
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        let packed = pack_batch(&refs);
        for threads in [1, 2] {
            let (cols, oh, ow) = im2col_batch(&packed, 3, 2, 6, 6, 3, 3, 1, 1, threads);
            let n = oh * ow;
            for (bi, x) in xs.iter().enumerate() {
                let (single, soh, sow) = im2col(x, 3, 3, 1, 1);
                assert_eq!((soh, sow), (oh, ow));
                let kdim = 2 * 3 * 3;
                for r in 0..kdim {
                    assert_eq!(
                        &cols[r * 3 * n + bi * n..r * 3 * n + (bi + 1) * n],
                        &single[r * n..(r + 1) * n],
                        "image {bi} row {r} (threads {threads})"
                    );
                }
            }
        }
    }

    #[test]
    fn batched_conv_ops_match_loop_of_singles() {
        let mut rng = Pcg32::seeded(19);
        let (cin, cout, hw, b) = (3, 4, 8, 5);
        let xs: Vec<Tensor<f32>> =
            (0..b).map(|_| rand_tensor(&mut rng, Shape::d3(cin, hw, hw))).collect();
        let k = rand_tensor(&mut rng, Shape::d4(cout, cin, 3, 3));
        let refs: Vec<&Tensor<f32>> = xs.iter().collect();
        let packed = pack_batch(&refs);
        let (cols, oh, ow) = im2col_batch(&packed, b, cin, hw, hw, 3, 3, 1, 1, 1);
        let n = oh * ow;
        let y = conv_forward_batch(&cols, &k, b * n, 1);
        let singles: Vec<Tensor<f32>> = xs.iter().map(|x| forward(x, &k, 1, 1)).collect();
        for (bi, s) in singles.iter().enumerate() {
            for c in 0..cout {
                assert_close(
                    &y[(c * b + bi) * n..(c * b + bi + 1) * n],
                    &s.data()[c * n..(c + 1) * n],
                    1e-5,
                    &format!("forward image {bi} channel {c}"),
                );
            }
        }

        // Input gradient: batched vs per-image.
        let dys: Vec<Tensor<f32>> =
            (0..b).map(|_| rand_tensor(&mut rng, Shape::d3(cout, oh, ow))).collect();
        let dy_refs: Vec<&Tensor<f32>> = dys.iter().collect();
        let dy_packed = pack_batch(&dy_refs);
        let dx = conv_input_grad_batch(&dy_packed, &k, b, hw, hw, 1, 1, oh, ow, 1);
        for (bi, dyi) in dys.iter().enumerate() {
            let single = input_grad(dyi, &k, &Shape::d3(cin, hw, hw), 1, 1);
            for c in 0..cin {
                assert_close(
                    &dx[(c * b + bi) * hw * hw..(c * b + bi + 1) * hw * hw],
                    &single.data()[c * hw * hw..(c + 1) * hw * hw],
                    1e-5,
                    &format!("input_grad image {bi} channel {c}"),
                );
            }
        }

        // Kernel gradient: batched sum vs sum of per-image gradients.
        let dk = conv_kernel_grad_batch(&dy_packed, &cols, k.shape(), b * n, 1);
        let mut dk_sum = vec![0.0f32; k.shape().numel()];
        for (x, dyi) in xs.iter().zip(&dys) {
            let g = kernel_grad(dyi, x, k.shape(), 1, 1);
            for (acc, &v) in dk_sum.iter_mut().zip(g.data()) {
                *acc += v;
            }
        }
        assert_close(dk.data(), &dk_sum, 1e-4, "kernel_grad batch sum");
    }

    #[test]
    fn batched_dense_ops_match_loop_of_singles() {
        let mut rng = Pcg32::seeded(23);
        let (n_in, n_out, b) = (40, 7, 4);
        let w = rand_tensor(&mut rng, Shape::d2(n_in, n_out));
        let x = rand_vec(&mut rng, b * n_in);
        let dy = rand_vec(&mut rng, b * n_out);

        let y = dense_forward_batch(&x, &w, b, 1);
        let dx = dense_input_grad_batch(&dy, &w, b, 1);
        for bi in 0..b {
            let xi = &x[bi * n_in..(bi + 1) * n_in];
            let dyi = &dy[bi * n_out..(bi + 1) * n_out];
            assert_close(
                &y[bi * n_out..(bi + 1) * n_out],
                &dense::forward(xi, &w),
                1e-5,
                &format!("dense fwd row {bi}"),
            );
            assert_close(
                &dx[bi * n_in..(bi + 1) * n_in],
                &dense::input_grad(dyi, &w),
                1e-5,
                &format!("dense dX row {bi}"),
            );
        }

        let dw = dense_weight_grad_batch(&dy, &x, b, n_in, n_out, 1);
        let mut dw_sum = vec![0.0f32; n_in * n_out];
        for bi in 0..b {
            let dyi = &dy[bi * n_out..(bi + 1) * n_out];
            let xi = &x[bi * n_in..(bi + 1) * n_in];
            let g = dense::weight_grad(dyi, xi);
            for (acc, &v) in dw_sum.iter_mut().zip(g.data()) {
                *acc += v;
            }
        }
        assert_close(dw.data(), &dw_sum, 1e-4, "dense dW batch sum");
    }
}
