//! Dense layer: forward (Eq. 4), input gradient (Eq. 5), weight gradient
//! (Eq. 6). Weights are stored `(in, out)` row-major, matching Eq. (4)'s
//! `W_{i,n}` indexing.

use crate::tensor::{Shape, Tensor};

/// `y_n = Σ_i I_i · W_{i,n}` — Eq. (4).
pub fn forward(x: &[f32], w: &Tensor<f32>) -> Vec<f32> {
    let [n_in, n_out]: [usize; 2] = w.shape().dims().try_into().expect("w must be 2D");
    assert_eq!(x.len(), n_in, "input length {} vs weight rows {n_in}", x.len());
    let wd = w.data();
    let mut y = vec![0.0f32; n_out];
    for i in 0..n_in {
        let xi = x[i];
        if xi == 0.0 {
            continue; // post-ReLU inputs are often sparse
        }
        let row = &wd[i * n_out..(i + 1) * n_out];
        for (n, wn) in row.iter().enumerate() {
            y[n] += xi * wn;
        }
    }
    y
}

/// Batched forward reference: one per-sample matvec per row of the
/// sample-major input `x (B × Nin)` — the parity oracle for
/// `nn::gemm::dense_forward_batch`'s single `B×Nin·Nin×Nout` GEMM.
pub fn forward_batch(x: &[f32], w: &Tensor<f32>, batch: usize) -> Vec<f32> {
    let n_in = w.shape().dims()[0];
    assert_eq!(x.len(), batch * n_in, "x must be B×Nin");
    x.chunks_exact(n_in).flat_map(|row| forward(row, w)).collect()
}

/// `dX_i = Σ_n dY_n · W_{i,n}` — Eq. (5).
pub fn input_grad(dy: &[f32], w: &Tensor<f32>) -> Vec<f32> {
    let [n_in, n_out]: [usize; 2] = w.shape().dims().try_into().expect("w must be 2D");
    assert_eq!(dy.len(), n_out);
    let wd = w.data();
    let mut dx = vec![0.0f32; n_in];
    for i in 0..n_in {
        let row = &wd[i * n_out..(i + 1) * n_out];
        let mut acc = 0.0f32;
        for (n, wn) in row.iter().enumerate() {
            acc += dy[n] * wn;
        }
        dx[i] = acc;
    }
    dx
}

/// `dW_{i,n} = I_i · dY_n` — Eq. (6), an outer product.
pub fn weight_grad(dy: &[f32], x: &[f32]) -> Tensor<f32> {
    let mut dw = Tensor::zeros(Shape::d2(x.len(), dy.len()));
    let n_out = dy.len();
    let data = dw.data_mut();
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &mut data[i * n_out..(i + 1) * n_out];
        for (n, &g) in dy.iter().enumerate() {
            row[n] = xi * g;
        }
    }
    dw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn forward_known_values() {
        // W = [[1,2],[3,4],[5,6]] (in=3, out=2); x = [1,1,1] → y = [9,12].
        let w = Tensor::from_vec(Shape::d2(3, 2), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(forward(&[1., 1., 1.], &w), vec![9., 12.]);
        // x = [1,0,0] picks out the first row.
        assert_eq!(forward(&[1., 0., 0.], &w), vec![1., 2.]);
    }

    #[test]
    fn input_grad_is_w_transpose_times_dy() {
        let w = Tensor::from_vec(Shape::d2(3, 2), vec![1., 2., 3., 4., 5., 6.]);
        // dX_i = Σ_n dY_n W_{i,n}; dY = [1, 10] → dX = [21, 43, 65].
        assert_eq!(input_grad(&[1., 10.], &w), vec![21., 43., 65.]);
    }

    #[test]
    fn weight_grad_outer_product() {
        let dw = weight_grad(&[2., 3.], &[1., 10.]);
        assert_eq!(dw.shape().dims(), &[2, 2]);
        assert_eq!(dw.data(), &[2., 3., 20., 30.]);
    }

    #[test]
    fn grads_match_finite_difference() {
        check("dense grads ~ finite diff", 61, 20, |g| {
            let n_in = g.usize_in(2, 10);
            let n_out = g.usize_in(1, 6);
            let x: Vec<f32> = (0..n_in).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let wvec: Vec<f32> = (0..n_in * n_out).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let w = Tensor::from_vec(Shape::d2(n_in, n_out), wvec);
            let dy: Vec<f32> = (0..n_out).map(|_| g.f32_in(-1.0, 1.0)).collect();

            let loss = |x: &[f32], w: &Tensor<f32>| -> f32 {
                forward(x, w).iter().zip(&dy).map(|(a, b)| a * b).sum()
            };
            let dx = input_grad(&dy, &w);
            let dw = weight_grad(&dy, &x);
            let eps = 1e-2f32;

            for i in 0..n_in {
                let mut xp = x.clone();
                xp[i] += eps;
                let mut xm = x.clone();
                xm[i] -= eps;
                let fd = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * eps);
                assert!((fd - dx[i]).abs() < 1e-2, "dx[{i}] fd={fd} got={}", dx[i]);
            }
            let j = g.usize_in(0, n_in * n_out - 1);
            let mut wp = w.clone();
            wp.data_mut()[j] += eps;
            let mut wm = w.clone();
            wm.data_mut()[j] -= eps;
            let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps);
            assert!((fd - dw.data()[j]).abs() < 1e-2);
        });
    }
}
