//! Plain SGD parameter update (the paper's optimizer; lr = 1, batch 1).

use crate::tensor::Tensor;

/// `w <- w - lr * g`, in place.
pub fn step(w: &mut Tensor<f32>, g: &Tensor<f32>, lr: f32) {
    assert_eq!(w.shape(), g.shape());
    for (wi, gi) in w.data_mut().iter_mut().zip(g.data()) {
        *wi -= lr * gi;
    }
}

/// Gradient-norm clipping (stabilizes lr=1 fixed-point-style training on
/// the float path; threshold ∞ disables it).
pub fn clip_by_norm(g: &mut Tensor<f32>, max_norm: f32) {
    if !max_norm.is_finite() {
        return;
    }
    let norm = g.l2_norm();
    if norm > max_norm && norm > 0.0 {
        let k = max_norm / norm;
        for v in g.data_mut() {
            *v *= k;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;

    #[test]
    fn step_applies_lr() {
        let mut w = Tensor::from_vec(Shape::d1(2), vec![1.0, 2.0]);
        let g = Tensor::from_vec(Shape::d1(2), vec![0.5, -0.5]);
        step(&mut w, &g, 2.0);
        assert_eq!(w.data(), &[0.0, 3.0]);
    }

    #[test]
    fn clip_scales_down_only() {
        let mut g = Tensor::from_vec(Shape::d1(2), vec![3.0, 4.0]); // norm 5
        clip_by_norm(&mut g, 1.0);
        assert!((g.l2_norm() - 1.0).abs() < 1e-6);
        let mut g2 = Tensor::from_vec(Shape::d1(2), vec![0.3, 0.4]);
        clip_by_norm(&mut g2, 1.0);
        assert_eq!(g2.data(), &[0.3, 0.4]);
    }

    #[test]
    fn infinite_threshold_noop() {
        let mut g = Tensor::from_vec(Shape::d1(2), vec![30.0, 40.0]);
        clip_by_norm(&mut g, f32::INFINITY);
        assert_eq!(g.data(), &[30.0, 40.0]);
    }
}
