//! E3 — Table I: TinyCL vs related DNN-training architectures.
//!
//! The comparator rows are the cited papers' constants; the TinyCL row is
//! computed by the cost model from the design point + measured activity,
//! so this bench fails if the model drifts off the paper's corner.
//! Run: `cargo bench --bench table1`.

use tinycl::fixed::Fx;
use tinycl::hw::comparison::{related_work, render_table1, table1_rows, tinycl_row};
use tinycl::hw::CostModel;
use tinycl::nn::{Model, ModelConfig};
use tinycl::qnn::QModel;
use tinycl::sim::{SimConfig, TinyClDevice};
use tinycl::tensor::{quantize_tensor, Shape, Tensor};
use tinycl::util::rng::Pcg32;

fn main() {
    let cfg = ModelConfig::default();
    let m = Model::new(cfg.clone(), 11);
    let qm = QModel::from_model(&m);
    let mut dev = TinyClDevice::new(SimConfig::paper(), cfg.clone());
    dev.load_params(&qm.params);
    let mut rng = Pcg32::seeded(12);
    let shape = Shape::d3(3, 32, 32);
    let n = shape.numel();
    let x = quantize_tensor(&Tensor::from_vec(
        shape,
        (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
    ));
    let (_, _, run) = dev.train_step(&x, 0, 10, Fx::from_f32(0.5));

    let cost = CostModel::paper();
    println!("E3: Table I — comparison with DNN-training accelerators\n");
    print!("{}", render_table1(&table1_rows(&cost, &run)));
    println!("\npaper row: TinyCL 3.87 ns / 86 mW / 4.74 mm² / 0.037 TOPS");

    // The paper's claim: lowest latency (clock period), power, and area
    // of the cohort. Verify the *ordering*, which is the table's point.
    let ours = tinycl_row(&cost, &run);
    for r in related_work() {
        assert!(ours.latency_ns < r.latency_ns, "latency vs {}", r.name);
        assert!(ours.power_mw < r.power_mw, "power vs {}", r.name);
        assert!(ours.area_mm2 < r.area_mm2, "area vs {}", r.name);
        // …and the honest flip side: far lower raw throughput.
        assert!(ours.perf_tops < r.perf_tops, "TOPS vs {}", r.name);
    }
    assert!((ours.perf_tops - 0.037).abs() < 0.002, "peak TOPS {}", ours.perf_tops);
    println!("E3 PASS: TinyCL wins latency/power/area, loses raw TOPS — the paper's trade");
}
