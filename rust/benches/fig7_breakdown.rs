//! E2 — Fig. 7 area & power breakdown.
//!
//! Regenerates the paper's per-component pie (memory ≈80 % of area,
//! ≈76 % of power at the synthesized design point) from the analytical
//! 65 nm model priced with a measured train-step activity window.
//! Run: `cargo bench --bench fig7_breakdown`.

use tinycl::fixed::Fx;
use tinycl::hw::CostModel;
use tinycl::nn::{Model, ModelConfig};
use tinycl::qnn::QModel;
use tinycl::sim::{SimConfig, TinyClDevice};
use tinycl::tensor::{quantize_tensor, Shape, Tensor};
use tinycl::util::rng::Pcg32;

fn main() {
    let cfg = ModelConfig::default();
    let m = Model::new(cfg.clone(), 7);
    let qm = QModel::from_model(&m);
    let mut dev = TinyClDevice::new(SimConfig::paper(), cfg.clone());
    dev.load_params(&qm.params);
    let mut rng = Pcg32::seeded(8);
    let shape = Shape::d3(3, 32, 32);
    let n = shape.numel();
    let x = quantize_tensor(&Tensor::from_vec(
        shape,
        (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
    ));
    let (_, _, run) = dev.train_step(&x, 0, 10, Fx::from_f32(0.5));

    let cost = CostModel::paper();
    let area = cost.area_mm2();
    let power = cost.power_mw(&run);

    println!("E2: Fig. 7 breakdown at the paper design point\n");
    println!("(a) area [mm²]          measured        paper");
    for (name, v) in area.rows() {
        println!(
            "  {:<16} {:>7.3} ({:>5.1}%)   {}",
            name,
            v,
            100.0 * v / area.total(),
            if name == "Memory" { "≈80%" } else { "—" }
        );
    }
    println!("  {:<16} {:>7.3}           4.74 mm²", "TOTAL", area.total());

    println!("\n(b) power [mW]          measured        paper");
    for (name, v) in power.rows() {
        println!(
            "  {:<16} {:>7.2} ({:>5.1}%)   {}",
            name,
            v,
            100.0 * v / power.total(),
            if name == "Memory" { "≈76%" } else { "—" }
        );
    }
    println!("  {:<16} {:>7.2}           86 mW", "TOTAL", power.total());

    let a_frac = area.memory_fraction();
    let p_frac = power.memory_fraction();
    assert!((a_frac - 0.80).abs() < 0.05, "area memory fraction {a_frac}");
    assert!((p_frac - 0.76).abs() < 0.05, "power memory fraction {p_frac}");
    assert!((area.total() - 4.74).abs() / 4.74 < 0.10);
    assert!((power.total() - 86.0).abs() / 86.0 < 0.10);
    println!("\nE2 PASS: memory dominates both axes at the paper's fractions");
}
