//! A2 — design-space ablation: why 9 MACs × 8 lanes?
//!
//! Sweeps the PU shape (taps × lanes) and prices every point with the
//! same cost model: cycles per train step, clock, area, average power and
//! energy per step. The paper's point should sit at the knee — smaller
//! designs burn more energy per step (longer runtime at similar power),
//! bigger ones pay area/power for utilization they cannot sustain on a
//! 3×3-kernel workload. Run: `cargo bench --bench ablation_design_space`.

use tinycl::fixed::Fx;
use tinycl::hw::{CostModel, EnergyModel};
use tinycl::nn::{Model, ModelConfig};
use tinycl::qnn::QModel;
use tinycl::sim::{RunStats, SimConfig, TinyClDevice};
use tinycl::tensor::{quantize_tensor, Shape, Tensor};
use tinycl::util::rng::Pcg32;

fn run_step(cfg: &ModelConfig, sim: &SimConfig) -> RunStats {
    let m = Model::new(cfg.clone(), 31);
    let qm = QModel::from_model(&m);
    let mut dev = TinyClDevice::new(sim.clone(), cfg.clone());
    dev.load_params(&qm.params);
    let mut rng = Pcg32::seeded(32);
    let shape = Shape::d3(cfg.in_channels, cfg.image_size, cfg.image_size);
    let n = shape.numel();
    let x = quantize_tensor(&Tensor::from_vec(
        shape,
        (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
    ));
    let (_, _, run) = dev.train_step(&x, 0, cfg.num_classes, Fx::from_f32(0.25));
    run
}

struct Point {
    lanes: usize,
    cycles: u64,
    step_us: f64,
    area: f64,
    power: f64,
    uj_per_step: f64,
}

fn main() {
    let cfg = ModelConfig::default();
    println!("A2: design-space sweep at the paper workload (32×32×3 → 10 classes)\n");
    println!(
        "{:<6} {:<6} {:>12} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "taps", "lanes", "cycles/step", "µs/step", "area mm²", "mW", "µJ/step", "µJ·mm² (EDP')"
    );

    let mut paper_point = None;
    let mut points = Vec::new();
    for lanes in [2usize, 4, 8, 16] {
        let sim = SimConfig::paper().with_lanes(lanes);
        let run = run_step(&cfg, &sim);
        let cost = CostModel::for_design(&sim, &cfg);
        let energy = EnergyModel::new(CostModel::for_design(&sim, &cfg));
        let step_us = run.cycles() as f64 * cost.clock_ns() * 1e-3;
        let uj = energy.report(&run, 0).total_uj();
        let area = cost.area_mm2().total();
        let power = cost.power_mw(&run).total();
        println!(
            "{:<6} {:<6} {:>12} {:>10.1} {:>10.2} {:>10.1} {:>10.2} {:>12.2}",
            9, lanes, run.cycles(), step_us, area, power, uj, uj * area
        );
        let p = Point { lanes, cycles: run.cycles(), step_us, area, power, uj_per_step: uj };
        if lanes == 8 {
            paper_point = Some(points.len());
        }
        points.push(p);
    }

    // Shape checks that make this an ablation rather than a printout:
    // latency strictly improves with lanes; area/power strictly grow;
    // the energy-delay-area product is minimized at (or adjacent to)
    // the paper's 8-lane point.
    for w in points.windows(2) {
        assert!(w[1].cycles <= w[0].cycles, "more lanes must not cost cycles");
        assert!(w[1].area > w[0].area, "more lanes must cost area");
        assert!(w[1].power > w[0].power, "more lanes must cost power");
        assert!(w[1].step_us < w[0].step_us);
    }
    let paper = paper_point.expect("paper point in sweep");
    let metric = |p: &Point| p.uj_per_step * p.area * p.step_us; // energy·delay·area
    let best = points
        .iter()
        .enumerate()
        .min_by(|a, b| metric(a.1).partial_cmp(&metric(b.1)).unwrap())
        .unwrap()
        .0;
    println!(
        "\nenergy·delay·area optimum at {} lanes (paper picked 8 — {})",
        points[best].lanes,
        if best == paper || best.abs_diff(paper) == 1 {
            "on/adjacent to the knee"
        } else {
            "off the knee on this workload"
        }
    );
    assert!(
        best.abs_diff(paper) <= 1,
        "paper design point is not at/adjacent to the EDA optimum"
    );
    println!("A2 PASS");
}
