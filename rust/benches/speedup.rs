//! E4 — §IV-C speedup, three rungs of the software ladder plus the
//! device:
//!
//! 1. **naive f32 vs `f32-fast`** (PR 1's compute core): one full
//!    forward+backward train step at the paper geometry (Conv 3→8 @
//!    32×32 + Conv 8→8 + Dense 8192→10, batch 1). The im2col+GEMM core
//!    must win by ≥ 5× — asserted, so this bench is a perf regression
//!    gate.
//! 2. **batch-1 `f32-fast` vs batched+threaded `f32-fast`** (PR 2's
//!    training engine): the same epoch trained in minibatches
//!    (`--batch`, default 8) with the GEMM column loops sharded across
//!    scoped workers (`--threads`, default auto). Must win by ≥ 2× on
//!    epoch wall-clock — asserted — and be **bit-identical** to
//!    threads=1 — also asserted.
//! 2b. **naive qnn vs fast qnn** (PR 3's integer engine): the bit-exact
//!    Q4.12 oracle's epoch, per-element loops vs the integer
//!    im2col+GEMM fast path on the persistent worker pool. Must win by
//!    ≥ 4× — asserted — and be **bit-identical** to the naive oracle on
//!    losses and every parameter — also asserted.
//! 2c. **`gemm` rung family (this PR's microkernels)**: the serve-path
//!    batched forward with the pre-PR kernels (fresh allocations,
//!    unfused zero-skip GEMMs, per-call weight reads) vs the
//!    register-tiled path behind `Model::forward_batch` on a packed
//!    weight snapshot (fused conv+ReLU epilogues, recycled scratch).
//!    Must win by ≥ 2× — asserted — and produce **identical logits** —
//!    also asserted. A micro-rung times the zero-skip kernel against
//!    the tiled one at the two serve shapes to pin where each pays:
//!    skipa must keep winning on the sparse-A/tiny-N dense layer, the
//!    tiled kernel on the dense-A/wide-N convs.
//! 3. **TinyCL device vs software**: one training epoch on the
//!    cycle-accurate sim (cycles × synthesized clock) vs the fastest
//!    host baseline, with the paper's P100 constants for reference. The
//!    AOT-XLA baseline joins in when built with `--features xla` (needs
//!    `make artifacts` + a PJRT plugin).
//!
//! Results are also emitted as machine-readable `BENCH_speedup.json`
//! (geometry, batch, threads, ns/step, speedups) so the perf trajectory
//! can be tracked across PRs.
//!
//! Run: `cargo bench --bench speedup [-- --steps N --batch N --threads N]`.
//! `-- --smoke` runs a tiny geometry with the wall-clock-ratio asserts
//! relaxed (CI uses it so the rungs can't rot on slow shared runners).

use tinycl::cl::Learner;
use tinycl::coordinator::{Backend, BackendKind};
use tinycl::data::SyntheticCifar;
use tinycl::fixed::Fx;
use tinycl::hw::CostModel;
use tinycl::nn::{gemm, Engine, Model, ModelConfig};
use tinycl::qnn::{QModel, QnnEngine};
use tinycl::sim::SimConfig;
use tinycl::tensor::{quantize_tensor, Tensor};
use tinycl::util::cli::Args;
use tinycl::util::json::{Json, Obj};
use tinycl::util::rng::Pcg32;

fn main() {
    let args = Args::from_env();
    let smoke = args.bool_or("smoke", false);
    // The paper's "1 epoch … in 1.76 s" works out to 10,000 train steps
    // (10 passes over the 1000-sample GDumb memory: 45,486 cycles/step ×
    // 3.87 ns × 10,000 = 1.76 s — see EXPERIMENTS.md E4). We measure a
    // few hundred steps and extrapolate linearly; exact for the sim
    // (cycles/step is constant), conservative for the host paths
    // (warmup amortizes further).
    let steps = args.usize_or("steps", if smoke { 48 } else { 250 });
    let batch = args.usize_or("batch", 8).max(1);
    let threads = args.threads_or_auto("threads", 0);
    let epoch_steps = 10_000.0;
    let cfg = if smoke {
        ModelConfig {
            in_channels: 3,
            image_size: 8,
            conv_channels: 4,
            num_classes: 4,
            grad_clip: f32::INFINITY,
        }
    } else {
        ModelConfig::default()
    };
    let sim_cfg = SimConfig::paper();

    let gen = SyntheticCifar {
        image_size: cfg.image_size,
        channels: cfg.in_channels,
        num_classes: cfg.num_classes,
        noise: 0.35,
        seed: 3,
    };
    let per_class = steps.div_ceil(cfg.num_classes).max(1);
    let data = gen.generate(per_class, 0);
    let samples: Vec<_> = data.samples.iter().take(steps).collect();
    assert!(!samples.is_empty());

    let mode = if smoke { "smoke" } else { "paper" };
    println!("E4 [{mode}]: 1 training epoch, Conv+ReLU+Conv+ReLU+Dense (§IV-C)\n");

    // --- Rung 1: naive f32 vs im2col+GEMM f32-fast, batch 1 ---
    let time_host = |kind: BackendKind| -> f64 {
        let mut backend =
            Backend::create(kind, &cfg, &sim_cfg, "artifacts", 3).expect("host backend");
        // One warmup step primes caches and the allocator.
        backend.train_step(&samples[0].x, samples[0].label, cfg.num_classes, 0.125);
        let t0 = std::time::Instant::now();
        for s in &samples {
            backend.train_step(&s.x, s.label, cfg.num_classes, 0.125);
        }
        t0.elapsed().as_secs_f64() / steps as f64
    };
    let naive_step = time_host(BackendKind::F32);
    let fast_step = time_host(BackendKind::F32Fast);
    let host_speedup = naive_step / fast_step;
    println!("per train step (forward+backward+update), batch 1:");
    println!("  f32 naive  : {:.3} ms", naive_step * 1e3);
    println!("  f32-fast   : {:.3} ms   ({host_speedup:.1}× over naive)", fast_step * 1e3);

    // --- Rung 2: batched + threaded f32-fast (PR 2's training engine) ---
    let time_batched = |threads: usize| -> f64 {
        let mut backend = Backend::create(BackendKind::F32Fast, &cfg, &sim_cfg, "artifacts", 3)
            .expect("host backend");
        backend.set_threads(threads);
        let warm = &samples[..batch.min(samples.len())];
        let xs: Vec<&Tensor<f32>> = warm.iter().map(|s| &s.x).collect();
        let labels: Vec<usize> = warm.iter().map(|s| s.label).collect();
        backend.train_batch(&xs, &labels, cfg.num_classes, 0.125);
        let t0 = std::time::Instant::now();
        for chunk in samples.chunks(batch) {
            let xs: Vec<&Tensor<f32>> = chunk.iter().map(|s| &s.x).collect();
            let labels: Vec<usize> = chunk.iter().map(|s| s.label).collect();
            backend.train_batch(&xs, &labels, cfg.num_classes, 0.125);
        }
        t0.elapsed().as_secs_f64() / samples.len() as f64
    };
    let batched_step = time_batched(threads);
    let batched_speedup = fast_step / batched_step;
    println!(
        "  batched    : {:.3} ms/sample (batch {batch}, {threads} threads; \
         {batched_speedup:.1}× over batch-1 f32-fast)",
        batched_step * 1e3
    );

    // --- Rung 2b (PR 3): the Q4.12 oracle — naive loops vs the
    // bit-identical integer im2col+GEMM engine, batch 1 (the paper's
    // training regime). The fast rung uses the same thread budget as
    // the batched f32 rung; the naive rung is inherently serial.
    let time_qnn = |engine: QnnEngine, qthreads: usize| -> f64 {
        let mut backend = Backend::create(BackendKind::Qnn, &cfg, &sim_cfg, "artifacts", 3)
            .expect("qnn backend");
        backend.set_qnn_engine(engine);
        backend.set_threads(qthreads);
        backend.train_step(&samples[0].x, samples[0].label, cfg.num_classes, 0.125);
        let t0 = std::time::Instant::now();
        for s in &samples {
            backend.train_step(&s.x, s.label, cfg.num_classes, 0.125);
        }
        t0.elapsed().as_secs_f64() / steps as f64
    };
    let qnn_naive_step = time_qnn(QnnEngine::Naive, 1);
    let qnn_fast_step = time_qnn(QnnEngine::Fast, threads);
    let qnn_speedup = qnn_naive_step / qnn_fast_step;
    println!(
        "  qnn naive  : {:.3} ms   (bit-exact Q4.12 oracle, per-element loops)",
        qnn_naive_step * 1e3
    );
    println!(
        "  qnn fast   : {:.3} ms   ({qnn_speedup:.1}× over naive qnn, integer im2col+GEMM)",
        qnn_fast_step * 1e3
    );

    // Bit-exactness gate for the qnn rung: the fast engine (threaded)
    // must reproduce the naive oracle exactly — losses and every
    // parameter bit — or the speedup is meaningless.
    {
        let m = Model::new(cfg.clone(), 7);
        let mut naive = QModel::from_model(&m).with_engine(QnnEngine::Naive);
        let mut fast =
            QModel::from_model(&m).with_engine(QnnEngine::Fast).with_threads(threads.max(2));
        let lr = Fx::from_f32(0.125);
        for s in samples.iter().take(3) {
            let xq = quantize_tensor(&s.x);
            let ln = naive.train_step(&xq, s.label, cfg.num_classes, lr);
            let lf = fast.train_step(&xq, s.label, cfg.num_classes, lr);
            assert_eq!(ln, lf, "qnn fast engine diverged from the naive oracle");
        }
        assert_eq!(naive.params.w.data(), fast.params.w.data(), "qnn w bits diverged");
        assert_eq!(naive.params.k1.data(), fast.params.k1.data(), "qnn k1 bits diverged");
        assert_eq!(naive.params.k2.data(), fast.params.k2.data(), "qnn k2 bits diverged");
        println!("  determinism: qnn fast (threads={}) bit-identical to naive ✓", threads.max(2));
    }

    // Determinism gate: thread sharding must not change a single bit.
    {
        let mut serial = Model::new(cfg.clone(), 7).with_engine(Engine::Gemm).with_threads(1);
        let mut sharded =
            Model::new(cfg.clone(), 7).with_engine(Engine::Gemm).with_threads(threads.max(2));
        for chunk in samples.chunks(batch).take(2) {
            let xs: Vec<&Tensor<f32>> = chunk.iter().map(|s| &s.x).collect();
            let labels: Vec<usize> = chunk.iter().map(|s| s.label).collect();
            let a = serial.train_batch(&xs, &labels, cfg.num_classes, 0.125).loss;
            let b = sharded.train_batch(&xs, &labels, cfg.num_classes, 0.125).loss;
            assert_eq!(a, b, "thread sharding changed the loss");
        }
        assert_eq!(
            serial.params.w.data(),
            sharded.params.w.data(),
            "thread sharding changed the trained weights"
        );
        println!("  determinism: threads={} bit-identical to threads=1 ✓", threads.max(2));
    }

    // --- Rung 2c (this PR): register-tiled serve-path microkernels ---
    // Reference: the pre-PR serve-path forward, reconstructed from the
    // kernels this PR kept verbatim — fresh allocations per call, the
    // zero-skip GEMM plus a separate ReLU pass for both convs, weights
    // read straight from the row-major tensors. The candidate is
    // `forward_batch` on a packed weight snapshot (what `clone_replica`
    // hands the serving replica pool): register-tiled microkernels,
    // fused conv+ReLU epilogues, recycled scratch.
    let serve_xs: Vec<&Tensor<f32>> = samples.iter().take(batch).map(|s| &s.x).collect();
    let (hw, cin, cc) = (cfg.image_size, cfg.in_channels, cfg.conv_channels);
    let spatial = hw * hw;
    let serve_b = serve_xs.len();
    let mut served = Model::new(cfg.clone(), 7).with_engine(Engine::Gemm).with_threads(threads);
    served.pack_weights();
    let params = served.params.clone();
    let serve_ref = |xs: &[&Tensor<f32>]| -> Vec<f32> {
        let b = xs.len();
        let bn = b * spatial;
        let x0 = gemm::pack_batch(xs);
        let (cols1, _, _) = gemm::im2col_batch(&x0, b, cin, hw, hw, 3, 3, 1, 1, threads);
        let mut a1 = vec![0.0f32; cc * bn];
        gemm::gemm_nn_skipa_mt(cc, cin * 9, bn, params.k1.data(), &cols1, &mut a1, threads);
        for v in &mut a1 {
            *v = v.max(0.0);
        }
        let (cols2, _, _) = gemm::im2col_batch(&a1, b, cc, hw, hw, 3, 3, 1, 1, threads);
        let mut a2 = vec![0.0f32; cc * bn];
        gemm::gemm_nn_skipa_mt(cc, cc * 9, bn, params.k2.data(), &cols2, &mut a2, threads);
        for v in &mut a2 {
            *v = v.max(0.0);
        }
        let xd = gemm::packed_to_rows(&a2, cc, b, spatial);
        gemm::dense_forward_batch(&xd, &params.w, b, threads)
    };
    let ref_logits = serve_ref(&serve_xs);
    let tiled_logits: Vec<f32> = served.forward_batch(&serve_xs).into_iter().flatten().collect();
    assert_eq!(ref_logits, tiled_logits, "microkernel serve path changed the logits");
    let serve_iters = if smoke { 20 } else { 200 };
    let t0 = std::time::Instant::now();
    for _ in 0..serve_iters {
        std::hint::black_box(serve_ref(&serve_xs));
    }
    let gemm_serve_ref_ns = t0.elapsed().as_nanos() as f64 / serve_iters as f64;
    let t0 = std::time::Instant::now();
    for _ in 0..serve_iters {
        std::hint::black_box(served.forward_batch(&serve_xs));
    }
    let gemm_serve_tiled_ns = t0.elapsed().as_nanos() as f64 / serve_iters as f64;
    let gemm_serve_speedup = gemm_serve_ref_ns / gemm_serve_tiled_ns;
    println!(
        "  gemm serve : {:.3} ms → {:.3} ms per batch-{serve_b} forward \
         ({gemm_serve_speedup:.1}× from register tiling + packing + fused ReLU; \
         logits identical ✓)",
        gemm_serve_ref_ns * 1e-6,
        gemm_serve_tiled_ns * 1e-6
    );

    // Micro-rung: zero-skip vs register-tiled at the two serve GEMM
    // shapes, pinning the per-layer kernel choice. The dense layer's A
    // is a post-ReLU activation matrix (~half zeros, N = classes) where
    // skipping zero rows of work still pays; the conv's A is a dense
    // kernel matrix with a wide N = B·Oh·Ow where the tiled kernel wins.
    let micro_iters = if smoke { 40 } else { 120 };
    let mut rng = Pcg32::seeded(11);
    let dense_in = cfg.dense_in();
    let classes = cfg.num_classes;
    let da: Vec<f32> = (0..serve_b * dense_in)
        .map(|_| rng.range_f32(-1.0, 1.0).max(0.0))
        .collect();
    let db: Vec<f32> = (0..dense_in * classes).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let kdim = cc * 9;
    let bn = serve_b * spatial;
    let ca: Vec<f32> = (0..cc * kdim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let cb: Vec<f32> = (0..kdim * bn).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let time_kernel = |f: &mut dyn FnMut()| -> f64 {
        f(); // warmup
        let t0 = std::time::Instant::now();
        for _ in 0..micro_iters {
            f();
        }
        t0.elapsed().as_nanos() as f64 / micro_iters as f64
    };
    let mut dc = vec![0.0f32; serve_b * classes];
    let gemm_dense_skipa_ns = time_kernel(&mut || {
        dc.fill(0.0);
        gemm::gemm_nn_skipa_mt(serve_b, dense_in, classes, &da, &db, &mut dc, threads);
    });
    let gemm_dense_tiled_ns = time_kernel(&mut || {
        dc.fill(0.0);
        gemm::gemm_nn_mt(serve_b, dense_in, classes, &da, &db, &mut dc, threads);
    });
    let mut cout = vec![0.0f32; cc * bn];
    let gemm_conv_skipa_ns = time_kernel(&mut || {
        cout.fill(0.0);
        gemm::gemm_nn_skipa_mt(cc, kdim, bn, &ca, &cb, &mut cout, threads);
    });
    let gemm_conv_tiled_ns = time_kernel(&mut || {
        cout.fill(0.0);
        gemm::gemm_nn_mt(cc, kdim, bn, &ca, &cb, &mut cout, threads);
    });
    println!(
        "  gemm micro : dense {serve_b}×{dense_in}×{classes} skipa {:.0} µs vs tiled {:.0} µs; \
         conv {cc}×{kdim}×{bn} skipa {:.0} µs vs tiled {:.0} µs",
        gemm_dense_skipa_ns * 1e-3,
        gemm_dense_tiled_ns * 1e-3,
        gemm_conv_skipa_ns * 1e-3,
        gemm_conv_tiled_ns * 1e-3
    );

    // --- Rung 3: TinyCL device (cycle-accurate sim @ 3.87 ns) ---
    let mut sim =
        Backend::create(BackendKind::Sim, &cfg, &sim_cfg, "artifacts", 3).expect("sim backend");
    let wall0 = std::time::Instant::now();
    for s in &samples {
        sim.train_step(&s.x, s.label, cfg.num_classes, 0.125);
    }
    let sim_wall = wall0.elapsed().as_secs_f64();
    let (train, _) = sim.sim_stats().unwrap();
    let cost = CostModel::for_design(&sim_cfg, &cfg);
    let cycles_per_step = train.cycles() as f64 / steps as f64;
    let tinycl_epoch = cycles_per_step * epoch_steps * cost.clock_ns() * 1e-9;

    // --- Software epoch: fastest host baseline (+ XLA when available) ---
    #[cfg(feature = "xla")]
    let xla_epoch: Option<f64> = {
        let mut xla = Backend::create(BackendKind::Xla, &cfg, &sim_cfg, "artifacts", 3)
            .expect("xla backend — build with --features xla and run `make artifacts`");
        xla.train_step(&samples[0].x, samples[0].label, cfg.num_classes, 0.125);
        let t0 = std::time::Instant::now();
        for s in &samples {
            xla.train_step(&s.x, s.label, cfg.num_classes, 0.125);
        }
        let e = t0.elapsed().as_secs_f64() / steps as f64 * epoch_steps;
        println!("  xla (AOT)  : {:.3} ms", e / epoch_steps * 1e3);
        Some(e)
    };
    #[cfg(not(feature = "xla"))]
    let xla_epoch: Option<f64> = None;

    let batched_epoch = batched_step * epoch_steps;
    let fast_epoch = fast_step * epoch_steps;
    let (sw_epoch, sw_label) = match xla_epoch {
        Some(x) if x < batched_epoch => (x, "xla AOT (this host)"),
        _ => (batched_epoch, "f32-fast batched (this host)"),
    };

    let speedup = sw_epoch / tinycl_epoch;
    println!("\nmeasured over {steps} steps, scaled to the paper's 10,000-step epoch:");
    println!(
        "  TinyCL device   : {:.3} s/epoch   ({:.0} cycles/step @ {:.2} ns)",
        tinycl_epoch, cycles_per_step, cost.clock_ns()
    );
    println!("  f32-fast b=1    : {fast_epoch:.3} s/epoch");
    println!("  software        : {sw_epoch:.3} s/epoch   [{sw_label}]");
    println!("  speedup         : {speedup:.1}×");
    println!("\npaper: 1.76 s vs 103 s on a P100 ⇒ 58× (their testbed; see EXPERIMENTS.md E4)");
    println!("(simulator wall time for reference: {sim_wall:.2} s for {steps} steps)");

    // --- Machine-readable result (perf trajectory across PRs; emitted
    // through the shared `util::json` writer) ---
    let mut geometry = Obj::new();
    geometry.put("image_size", cfg.image_size);
    geometry.put("in_channels", cfg.in_channels);
    geometry.put("conv_channels", cfg.conv_channels);
    geometry.put("classes", cfg.num_classes);
    let mut doc = Obj::new();
    doc.put("bench", "speedup");
    doc.put("mode", mode);
    doc.put("geometry", geometry.build());
    doc.put("steps", steps);
    doc.put("batch", batch);
    doc.put("threads", threads);
    doc.put("naive_ns_per_step", Json::fixed(naive_step * 1e9, 0));
    doc.put("fast_ns_per_step", Json::fixed(fast_step * 1e9, 0));
    doc.put("batched_ns_per_step", Json::fixed(batched_step * 1e9, 0));
    doc.put("qnn_naive_ns_per_step", Json::fixed(qnn_naive_step * 1e9, 0));
    doc.put("qnn_fast_ns_per_step", Json::fixed(qnn_fast_step * 1e9, 0));
    doc.put("gemm_serve_ref_ns", Json::fixed(gemm_serve_ref_ns, 0));
    doc.put("gemm_serve_tiled_ns", Json::fixed(gemm_serve_tiled_ns, 0));
    doc.put("gemm_serve_speedup", Json::fixed(gemm_serve_speedup, 2));
    doc.put("gemm_dense_skipa_ns", Json::fixed(gemm_dense_skipa_ns, 0));
    doc.put("gemm_dense_tiled_ns", Json::fixed(gemm_dense_tiled_ns, 0));
    doc.put("gemm_conv_skipa_ns", Json::fixed(gemm_conv_skipa_ns, 0));
    doc.put("gemm_conv_tiled_ns", Json::fixed(gemm_conv_tiled_ns, 0));
    doc.put("fast_speedup_over_naive", Json::fixed(host_speedup, 2));
    doc.put("batched_speedup_over_fast", Json::fixed(batched_speedup, 2));
    doc.put("qnn_fast_speedup_over_naive", Json::fixed(qnn_speedup, 2));
    doc.put("tinycl_epoch_secs", Json::fixed(tinycl_epoch, 4));
    doc.put("sw_epoch_secs", Json::fixed(sw_epoch, 4));
    let json = doc.build().to_pretty(2);
    match std::fs::write("BENCH_speedup.json", &json) {
        Ok(()) => println!("\nwrote BENCH_speedup.json"),
        Err(e) => eprintln!("\nWARN: could not write BENCH_speedup.json: {e}"),
    }

    // Shape assertions: each software rung and the device win by their
    // required factors, and the device's absolute epoch time lands on
    // the paper's figure (same cycle count, same clock). Wall-clock
    // ratios are asserted only at the paper geometry — the smoke rung
    // runs everything but tolerates slow shared runners.
    if !smoke {
        assert!(
            host_speedup >= 5.0,
            "f32-fast speedup {host_speedup:.1}× < 5× over naive — GEMM core regressed"
        );
        assert!(
            batched_speedup >= 2.0,
            "batched+threaded speedup {batched_speedup:.2}× < 2× over batch-1 f32-fast \
             (batch {batch}, {threads} threads) — training engine regressed"
        );
        assert!(
            qnn_speedup >= 4.0,
            "qnn fast speedup {qnn_speedup:.1}× < 4× over naive qnn — \
             integer GEMM engine regressed"
        );
        assert!(
            gemm_serve_speedup >= 2.0,
            "serve-path microkernel speedup {gemm_serve_speedup:.2}× < 2× over the pre-PR \
             kernels — register tiling / weight packing / fused epilogue regressed"
        );
        assert!(
            gemm_dense_skipa_ns <= gemm_dense_tiled_ns,
            "zero-skip lost its home turf: dense-layer skipa {gemm_dense_skipa_ns:.0} ns vs \
             tiled {gemm_dense_tiled_ns:.0} ns — revisit dense_forward_batch's kernel choice"
        );
        assert!(
            gemm_conv_tiled_ns <= gemm_conv_skipa_ns,
            "register-tiled conv GEMM {gemm_conv_tiled_ns:.0} ns slower than zero-skip \
             {gemm_conv_skipa_ns:.0} ns — revisit the conv-path kernel choice"
        );
        assert!((tinycl_epoch - 1.76).abs() < 0.3, "TinyCL epoch {tinycl_epoch} vs paper 1.76");
        assert!(speedup > 5.0, "speedup {speedup} lost the paper's ordering");
    }
    println!("\nE4 PASS");
}
