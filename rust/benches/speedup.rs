//! E4 — §IV-C speedup, two rungs of the software ladder plus the device:
//!
//! 1. **naive f32 vs `f32-fast`** (this PR's compute core): one full
//!    forward+backward train step at the paper geometry (Conv 3→8 @
//!    32×32 + Conv 8→8 + Dense 8192→10, batch 1). The im2col+GEMM core
//!    must win by ≥ 5× — asserted, so this bench is a perf regression
//!    gate.
//! 2. **TinyCL device vs software**: one training epoch on the
//!    cycle-accurate sim (cycles × synthesized clock) vs the fastest
//!    host baseline, with the paper's P100 constants for reference. The
//!    AOT-XLA baseline joins in when built with `--features xla` (needs
//!    `make artifacts` + a PJRT plugin).
//!
//! Run: `cargo bench --bench speedup [-- --steps N]`.

use tinycl::cl::Learner;
use tinycl::coordinator::{Backend, BackendKind};
use tinycl::data::SyntheticCifar;
use tinycl::hw::CostModel;
use tinycl::nn::ModelConfig;
use tinycl::sim::SimConfig;
use tinycl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    // The paper's "1 epoch … in 1.76 s" works out to 10,000 train steps
    // (10 passes over the 1000-sample GDumb memory: 45,486 cycles/step ×
    // 3.87 ns × 10,000 = 1.76 s — see EXPERIMENTS.md E4). We measure a
    // few hundred steps and extrapolate linearly; exact for the sim
    // (cycles/step is constant), conservative for the host paths
    // (warmup amortizes further).
    let steps = args.usize_or("steps", 250);
    let epoch_steps = 10_000.0;
    let cfg = ModelConfig::default();
    let sim_cfg = SimConfig::paper();

    let gen = SyntheticCifar::default();
    let data = gen.generate(steps.div_ceil(10).max(1), 0);
    let samples: Vec<_> = data.samples.iter().take(steps).collect();
    assert!(!samples.is_empty());

    println!("E4: 1 training epoch, Conv+ReLU+Conv+ReLU+Dense, batch 1 (§IV-C)\n");

    // --- Host rung: naive f32 vs im2col+GEMM f32-fast ---
    let time_host = |kind: BackendKind| -> f64 {
        let mut backend =
            Backend::create(kind, &cfg, &sim_cfg, "artifacts", 3).expect("host backend");
        // One warmup step primes caches and the allocator.
        backend.train_step(&samples[0].x, samples[0].label, cfg.num_classes, 0.125);
        let t0 = std::time::Instant::now();
        for s in &samples {
            backend.train_step(&s.x, s.label, cfg.num_classes, 0.125);
        }
        t0.elapsed().as_secs_f64() / steps as f64
    };
    let naive_step = time_host(BackendKind::F32);
    let fast_step = time_host(BackendKind::F32Fast);
    let host_speedup = naive_step / fast_step;
    println!("per train step (forward+backward+update) at the paper geometry:");
    println!("  f32 naive  : {:.3} ms", naive_step * 1e3);
    println!("  f32-fast   : {:.3} ms   ({host_speedup:.1}× over naive)", fast_step * 1e3);

    // --- TinyCL device (cycle-accurate sim @ 3.87 ns) ---
    let mut sim =
        Backend::create(BackendKind::Sim, &cfg, &sim_cfg, "artifacts", 3).expect("sim backend");
    let wall0 = std::time::Instant::now();
    for s in &samples {
        sim.train_step(&s.x, s.label, cfg.num_classes, 0.125);
    }
    let sim_wall = wall0.elapsed().as_secs_f64();
    let (train, _) = sim.sim_stats().unwrap();
    let cost = CostModel::for_design(&sim_cfg, &cfg);
    let cycles_per_step = train.cycles() as f64 / steps as f64;
    let tinycl_epoch = cycles_per_step * epoch_steps * cost.clock_ns() * 1e-9;

    // --- Software epoch: fastest host baseline (+ XLA when available) ---
    #[cfg(feature = "xla")]
    let xla_epoch: Option<f64> = {
        let mut xla = Backend::create(BackendKind::Xla, &cfg, &sim_cfg, "artifacts", 3)
            .expect("xla backend — build with --features xla and run `make artifacts`");
        xla.train_step(&samples[0].x, samples[0].label, cfg.num_classes, 0.125);
        let t0 = std::time::Instant::now();
        for s in &samples {
            xla.train_step(&s.x, s.label, cfg.num_classes, 0.125);
        }
        let e = t0.elapsed().as_secs_f64() / steps as f64 * epoch_steps;
        println!("  xla (AOT)  : {:.3} ms", e / epoch_steps * 1e3);
        Some(e)
    };
    #[cfg(not(feature = "xla"))]
    let xla_epoch: Option<f64> = None;

    let fast_epoch = fast_step * epoch_steps;
    let (sw_epoch, sw_label) = match xla_epoch {
        Some(x) if x < fast_epoch => (x, "xla AOT (this host)"),
        _ => (fast_epoch, "f32-fast (this host)"),
    };

    let speedup = sw_epoch / tinycl_epoch;
    println!("\nmeasured over {steps} steps, scaled to the paper's 10,000-step epoch:");
    println!(
        "  TinyCL device   : {:.3} s/epoch   ({:.0} cycles/step @ {:.2} ns)",
        tinycl_epoch, cycles_per_step, cost.clock_ns()
    );
    println!("  software        : {sw_epoch:.3} s/epoch   [{sw_label}]");
    println!("  speedup         : {speedup:.1}×");
    println!("\npaper: 1.76 s vs 103 s on a P100 ⇒ 58× (their testbed; see EXPERIMENTS.md E4)");
    println!("(simulator wall time for reference: {sim_wall:.2} s for {steps} steps)");

    // Shape assertions: the GEMM core and the device both win by the
    // required factors, and the device's absolute epoch time lands on
    // the paper's figure (same cycle count, same clock).
    assert!(
        host_speedup >= 5.0,
        "f32-fast speedup {host_speedup:.1}× < 5× over naive — GEMM core regressed"
    );
    assert!((tinycl_epoch - 1.76).abs() < 0.3, "TinyCL epoch {tinycl_epoch} vs paper 1.76");
    assert!(speedup > 5.0, "speedup {speedup} lost the paper's ordering");
    println!("\nE4 PASS");
}
