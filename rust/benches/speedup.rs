//! E4 — §IV-C speedup: one training epoch on the TinyCL device (cycles ×
//! synthesized clock) vs the *same* workload's software-level
//! implementation — the AOT JAX/Pallas artifacts executed via PJRT on
//! this host's CPU (the paper used TensorFlow on a P100; we carry their
//! constants alongside for reference).
//!
//! Run: `cargo bench --bench speedup [-- --steps N]`.
//! Requires `make artifacts`.

use tinycl::cl::Learner;
use tinycl::coordinator::{Backend, BackendKind};
use tinycl::data::SyntheticCifar;
use tinycl::hw::CostModel;
use tinycl::nn::ModelConfig;
use tinycl::sim::SimConfig;
use tinycl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    // The paper's "1 epoch … in 1.76 s" works out to 10,000 train steps
    // (10 passes over the 1000-sample GDumb memory: 45,486 cycles/step ×
    // 3.87 ns × 10,000 = 1.76 s — see EXPERIMENTS.md E4). We measure 250
    // steps and extrapolate linearly; exact for the sim (cycles/step is
    // constant), conservative for XLA (warmup amortizes further).
    let steps = args.usize_or("steps", 250);
    let epoch_steps = 10_000.0;
    let cfg = ModelConfig::default();
    let sim_cfg = SimConfig::paper();

    let gen = SyntheticCifar::default();
    let data = gen.generate(steps.div_ceil(10).max(1), 0);
    let samples: Vec<_> = data.samples.iter().take(steps).collect();
    assert!(!samples.is_empty());

    println!("E4: 1 training epoch, Conv+ReLU+Conv+ReLU+Dense, batch 1 (§IV-C)\n");

    // --- TinyCL device (cycle-accurate sim @ 3.87 ns) ---
    let mut sim = Backend::create(BackendKind::Sim, &cfg, &sim_cfg, "artifacts", 3)
        .expect("sim backend");
    let wall0 = std::time::Instant::now();
    for s in &samples {
        sim.train_step(&s.x, s.label, cfg.num_classes, 0.125);
    }
    let sim_wall = wall0.elapsed().as_secs_f64();
    let (train, _) = sim.sim_stats().unwrap();
    let cost = CostModel::for_design(&sim_cfg, &cfg);
    let cycles_per_step = train.cycles() as f64 / steps as f64;
    let tinycl_epoch = cycles_per_step * epoch_steps * cost.clock_ns() * 1e-9;

    // --- Software baseline: AOT JAX/Pallas via PJRT on this host ---
    let mut xla = Backend::create(BackendKind::Xla, &cfg, &sim_cfg, "artifacts", 3)
        .expect("xla backend — run `make artifacts`");
    // Warmup (compile path already done at create; one step primes caches).
    xla.train_step(&samples[0].x, samples[0].label, cfg.num_classes, 0.125);
    let t0 = std::time::Instant::now();
    for s in &samples {
        xla.train_step(&s.x, s.label, cfg.num_classes, 0.125);
    }
    let xla_epoch = t0.elapsed().as_secs_f64() / steps as f64 * epoch_steps;

    let speedup = xla_epoch / tinycl_epoch;
    println!("measured over {steps} steps, scaled to the paper's 10,000-step epoch:");
    println!(
        "  TinyCL device   : {:.3} s/epoch   ({:.0} cycles/step @ {:.2} ns)",
        tinycl_epoch, cycles_per_step, cost.clock_ns()
    );
    println!("  XLA CPU baseline: {xla_epoch:.3} s/epoch   (this host)");
    println!("  speedup         : {speedup:.1}×");
    println!("\npaper: 1.76 s vs 103 s on a P100 ⇒ 58× (their testbed; see EXPERIMENTS.md E4)");
    println!("(simulator wall time for reference: {sim_wall:.2} s for {steps} steps)");

    // Shape assertions: the device wins by a large factor, and its
    // absolute epoch time lands on the paper's figure (same cycle count,
    // same clock).
    assert!((tinycl_epoch - 1.76).abs() < 0.3, "TinyCL epoch {tinycl_epoch} vs paper 1.76");
    assert!(speedup > 5.0, "speedup {speedup} lost the paper's ordering");
    println!("\nE4 PASS");
}
