//! E1 — §IV-B per-operation cycle counts.
//!
//! Regenerates the paper's reported latencies at the paper's geometry
//! (32×32×8 input, 8 filters; dense 8192→10) and prints paper-vs-measured
//! side by side. Run: `cargo bench --bench cycles`.

use tinycl::fixed::Fx;
use tinycl::nn::{Model, ModelConfig};
use tinycl::qnn::QModel;
use tinycl::sim::{OpKind, SimConfig, TinyClDevice};
use tinycl::tensor::{quantize_tensor, Shape, Tensor};
use tinycl::util::rng::Pcg32;

fn main() {
    let cfg = ModelConfig::default();
    let sim = SimConfig::paper();
    let m = Model::new(cfg.clone(), 1);
    let qm = QModel::from_model(&m);
    let mut dev = TinyClDevice::new(sim.clone(), cfg.clone());
    dev.load_params(&qm.params);

    let mut rng = Pcg32::seeded(2);
    let shape = Shape::d3(3, 32, 32);
    let n = shape.numel();
    let x = quantize_tensor(&Tensor::from_vec(
        shape,
        (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
    ));
    let (_, _, run) = dev.train_step(&x, 0, 10, Fx::from_f32(0.5));

    // Paper §IV-B numbers. Conv ops are quoted per 32×32×8-in/8-filter
    // layer; a full train step runs conv forward ×2 and kernel grad ×2
    // (conv1's 3-channel input still costs one full channel-group sweep).
    // The dense dX/dW labels read swapped in the paper (see EXPERIMENTS.md
    // E1); we list what the paper's own formulas yield.
    let rows: &[(&str, OpKind, u64, u64)] = &[
        ("conv forward (×2)", OpKind::ConvForward, 8192, 2),
        ("conv kernel grad (×2)", OpKind::ConvKernelGrad, 8192, 2),
        ("conv grad propagation", OpKind::ConvInputGrad, 8192, 1),
        ("dense forward", OpKind::DenseForward, 1280, 1),
        ("dense grad propagation", OpKind::DenseInputGrad, 1822, 1),
        ("dense weight update", OpKind::DenseWeightUpdate, 1280, 1),
    ];

    println!("E1: §IV-B cycle counts at the paper design point (9 MACs × 8 lanes)");
    println!(
        "{:<26} {:>12} {:>12} {:>8}",
        "operation", "paper", "measured", "match"
    );
    let mut all_ok = true;
    for &(name, op, paper_each, times) in rows {
        let measured = run.by_op[&op].cycles;
        let expect = paper_each * times;
        // ±2 cycles per instance absorbs the paper's own ceil-split
        // ambiguity on the dense 1821/1822 figure.
        let ok = measured.abs_diff(expect) <= 2 * times;
        all_ok &= ok;
        println!(
            "{:<26} {:>12} {:>12} {:>8}",
            name,
            expect,
            measured,
            if ok { "OK" } else { "MISMATCH" }
        );
    }
    let total = run.cycles();
    println!(
        "{:<26} {:>12} {:>12}",
        "full train step", "~45.5k", total
    );
    println!(
        "\nat {:.2} ns: one step = {:.1} µs; 10 epochs × 1000 GDumb samples = {:.2} s (paper: 1.76 s)",
        dev.sim_cfg.clock_ns,
        total as f64 * dev.sim_cfg.clock_ns * 1e-3,
        total as f64 * 10_000.0 * dev.sim_cfg.clock_ns * 1e-9,
    );
    assert!(all_ok, "cycle-count mismatch vs §IV-B");
    println!("\nE1 PASS");
}
