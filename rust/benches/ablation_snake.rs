//! A1 — ablation: snake-like sliding window (Fig. 5) vs raster traversal.
//!
//! The paper's claim: the snake keeps 6 of 9 window columns resident so
//! each steady-state cycle fetches only 3 vectors; a raster scan reloads
//! the full 9-tap window at every row wrap. This bench measures actual
//! feature-SRAM reads per output pixel for both traversals and the
//! resulting memory-energy delta. Run: `cargo bench --bench ablation_snake`.

use tinycl::fixed::Fx;
use tinycl::hw::{CostModel, EnergyModel};
use tinycl::nn::{Model, ModelConfig};
use tinycl::qnn::QModel;
use tinycl::sim::{OpKind, RunStats, SimConfig, TinyClDevice};
use tinycl::tensor::{quantize_tensor, Shape, Tensor};
use tinycl::util::rng::Pcg32;

fn run_step(cfg: &ModelConfig, sim: SimConfig) -> RunStats {
    let m = Model::new(cfg.clone(), 21);
    let qm = QModel::from_model(&m);
    let mut dev = TinyClDevice::new(sim, cfg.clone());
    dev.load_params(&qm.params);
    let mut rng = Pcg32::seeded(22);
    let shape = Shape::d3(cfg.in_channels, cfg.image_size, cfg.image_size);
    let n = shape.numel();
    let x = quantize_tensor(&Tensor::from_vec(
        shape,
        (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
    ));
    let (_, _, run) = dev.train_step(&x, 0, cfg.num_classes, Fx::from_f32(0.25));
    run
}

fn main() {
    println!("A1: snake vs raster sliding window (conv ops of one train step)\n");
    println!(
        "{:<10} {:<8} {:>14} {:>16} {:>14} {:>12}",
        "image", "order", "conv cycles", "feature reads", "reads/pixel", "µJ (conv)"
    );

    for image_size in [16, 32, 64] {
        let cfg = ModelConfig { image_size, ..ModelConfig::default() };
        let mut per_order = Vec::new();
        for (name, snake, reuse) in
            [("snake", true, true), ("raster", false, true), ("no-reuse", false, false)]
        {
            let sim = SimConfig::paper().with_snake(snake).with_window_reuse(reuse);
            let run = run_step(&cfg, sim.clone());
            let conv = run.by_op[&OpKind::ConvForward];
            let energy = EnergyModel::new(CostModel::for_design(&sim, &cfg));
            let mut conv_only = RunStats::default();
            conv_only.record(OpKind::ConvForward, conv);
            conv_only.record(OpKind::ConvInputGrad, run.by_op[&OpKind::ConvInputGrad]);
            conv_only.record(OpKind::ConvKernelGrad, run.by_op[&OpKind::ConvKernelGrad]);
            let uj = energy.report(&conv_only, 0).on_die_uj;
            let pixels = conv.cycles as f64; // one output pixel per cycle
            let rpp = conv.feature_reads as f64 / pixels;
            println!(
                "{:<10} {:<8} {:>14} {:>16} {:>14.2} {:>12.2}",
                format!("{image_size}×{image_size}"),
                name,
                conv.cycles,
                conv.feature_reads,
                rpp,
                uj
            );
            per_order.push((conv.feature_reads, uj, run));
        }
        let (snake_reads, snake_uj, snake_run) = &per_order[0];
        let (raster_reads, _, raster_run) = &per_order[1];
        let (noreuse_reads, noreuse_uj, noreuse_run) = &per_order[2];
        println!(
            "{:<10} {:<8} snake vs raster reads ×{:.2}; vs no-reuse reads ×{:.2}, conv energy ×{:.2}\n",
            "",
            "→saving",
            *raster_reads as f64 / *snake_reads as f64,
            *noreuse_reads as f64 / *snake_reads as f64,
            noreuse_uj / snake_uj
        );
        // Same computation in every mode — identical non-memory activity.
        assert_eq!(snake_run.total().mults, raster_run.total().mults);
        assert_eq!(snake_run.total().mults, noreuse_run.total().mults);
        assert!(raster_reads > snake_reads, "raster must fetch more");
        assert!(noreuse_reads > raster_reads, "no-reuse must fetch most");
        // The paper's §III-F-1 claim: ~3 fetches per pixel with the snake
        // (vs 9 without reuse). Steady-state plus edge effects ⇒ < 3.1
        // at 32×32 and above.
        if image_size >= 32 {
            let conv = snake_run.by_op[&OpKind::ConvForward];
            assert!(conv.feature_reads as f64 / (conv.cycles as f64) < 3.1);
        }
    }

    println!("A1 PASS: snake traversal strictly reduces feature traffic");
}
