//! Latent-replay frontier bench: replay cut × byte budget vs the
//! raw-sample baselines at equal byte budgets — same driver as
//! `tinycl replay-bench` (see `cl::bench`), exposed as a bench binary so
//! `cargo bench --bench replay` sits next to the other paper-figure
//! benches.
//!
//! Run: `cargo bench --bench replay [-- --backend f32-fast|f32|qnn
//! --budgets-kb 6144,3072,1536 --tasks N --epochs N --batch N
//! --per-class N --threads N --qnn-engine naive|fast --seed N --smoke]`.
//!
//! For each byte budget it runs gdumb, er and latent-replay at every
//! cut, reports accuracy/forgetting/train time per point, and at the
//! paper geometry asserts an interior cut trains ≥ 2× faster than gdumb
//! at the largest budget. Emits `BENCH_replay.json`.

use tinycl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = tinycl::cl::bench::run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
