//! Serving bench: the replica-pool inference server under closed-loop
//! and open-loop load — same driver as `tinycl serve-bench` (see
//! `serve::bench`), exposed as a bench binary so `cargo bench --bench
//! serve` sits next to the other paper-figure benches.
//!
//! Run: `cargo bench --bench serve [-- --clients N --max-batch N
//! --replicas N --open-loop=false --slo=false --arrival-rate R
//! --arrival-process poisson|uniform --max-wait-us N --queue-depth N
//! --requests N --backend ... --threads N --qnn-engine naive|fast
//! --smoke]`.
//!
//! Ladders `max_batch = 1` vs `N` and `replicas = 1` vs `N` per
//! backend, sweeps an open-loop saturation ladder (coordinated-
//! omission-corrected latency, achieved-vs-offered knee), then runs
//! the SLO-attainment rung at 0.9× the knee: per-request deadlines,
//! serve-while-learning on, an injected replica kill mid-run healed by
//! the autoscaler at the next train barrier, diff-only weight
//! re-broadcast, and exactly-once accounting (zero duplicate or lost
//! responses). Parity-pins every served answer against per-sample
//! `predict`, checks the per-lane shed taxonomy
//! (`offered == admitted + shed_capacity + shed_deadline`), and at the
//! paper geometry asserts cross-request batching ≥ 2×, 2-replica
//! `f32-fast` ≥ 1.5×, and interactive SLO attainment ≥ 99%. Emits
//! `BENCH_serve.json`.

use tinycl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = tinycl::serve::bench::run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
