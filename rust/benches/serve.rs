//! Serving bench: the dynamic-batching inference server under
//! closed-loop multi-client load — same driver as `tinycl serve-bench`
//! (see `serve::bench`), exposed as a bench binary so `cargo bench
//! --bench serve` sits next to the other paper-figure benches.
//!
//! Run: `cargo bench --bench serve [-- --clients N --max-batch N
//! --max-wait-us N --queue-depth N --requests N --backend ...
//! --threads N --qnn-engine naive|fast --smoke]`.
//!
//! Ladders `max_batch = 1` vs `max_batch = N` per backend, parity-pins
//! every served answer against per-sample `predict`, checks the shed
//! accounting (`offered == admitted + shed`), and at the paper geometry
//! asserts cross-request batching wins ≥ 2× on `f32-fast` and `qnn`.
//! Emits `BENCH_serve.json`.

use tinycl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = tinycl::serve::bench::run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
