//! Serving bench: the replica-pool inference server under closed-loop
//! and open-loop load — same driver as `tinycl serve-bench` (see
//! `serve::bench`), exposed as a bench binary so `cargo bench --bench
//! serve` sits next to the other paper-figure benches.
//!
//! Run: `cargo bench --bench serve [-- --clients N --max-batch N
//! --replicas N --open-loop=false --arrival-rate R
//! --arrival-process poisson|uniform --max-wait-us N --queue-depth N
//! --requests N --backend ... --threads N --qnn-engine naive|fast
//! --smoke]`.
//!
//! Ladders `max_batch = 1` vs `N` and `replicas = 1` vs `N` per
//! backend, sweeps an open-loop saturation ladder (coordinated-
//! omission-corrected latency, achieved-vs-offered knee), parity-pins
//! every served answer against per-sample `predict`, checks the
//! per-lane shed accounting (`offered == admitted + shed`), and at the
//! paper geometry asserts cross-request batching ≥ 2× (`f32-fast`,
//! `qnn`) and 2-replica `f32-fast` ≥ 1.5×. Emits `BENCH_serve.json`.

use tinycl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = tinycl::serve::bench::run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
