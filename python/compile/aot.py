"""AOT lowering: JAX/Pallas (L2/L1) → HLO **text** artifacts for the Rust
runtime (L3).

HLO text — NOT ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids, which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids and round-trips cleanly. Lowering goes through
stablehlo → XlaComputation with ``return_tuple=True``; the Rust side
unwraps with ``to_tuple{N}()``. See /opt/xla-example/README.md.

Run via ``make artifacts`` (no-op when inputs are unchanged — the
manifest records a hash of every compile-path source).

Emitted per geometry (paper 32×32×8 and tiny 8×8×4):
* ``forward[_tiny].hlo.txt``    — (k1, k2, w, x) → (logits,)
* ``train_step[_tiny].hlo.txt`` — (k1, k2, w, x, onehot, mask, lr)
                                  → (k1', k2', w', loss, logits)
* ``manifest.txt``              — artifact inventory + source hash
"""

import argparse
import hashlib
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo → HLO text with return_tuple=True (see module doc)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(cfg: model.ModelConfig):
    """Lower both entry points for one geometry; returns {name: hlo}."""
    args = model.example_args(cfg)
    return {
        "forward": to_hlo_text(jax.jit(model.forward).lower(*args["forward"])),
        "train_step": to_hlo_text(jax.jit(model.train_step).lower(*args["train_step"])),
    }


def source_hash() -> str:
    """Hash of every compile-path source file (manifest freshness key)."""
    root = pathlib.Path(__file__).parent
    h = hashlib.sha256()
    for p in sorted(root.rglob("*.py")):
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    manifest = [f"source_hash {source_hash()}"]
    for suffix, cfg in (("", model.PAPER), ("_tiny", model.TINY)):
        for name, hlo in lower_all(cfg).items():
            path = out / f"{name}{suffix}.hlo.txt"
            path.write_text(hlo)
            manifest.append(
                f"{path.name} geometry=in{cfg.in_channels}x{cfg.image_size}"
                f"c{cfg.conv_channels}n{cfg.num_classes} chars={len(hlo)}"
            )
            print(f"wrote {path} ({len(hlo)} chars)")

    (out / "manifest.txt").write_text("\n".join(manifest) + "\n")
    print(f"wrote {out / 'manifest.txt'}")


if __name__ == "__main__":
    main()
