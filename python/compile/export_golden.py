"""Export golden test vectors from the jnp oracles into rust/tests/golden/.

The Rust crate carries two f32 implementations of every layer (naive
loops in ``nn::conv``/``nn::dense`` and the im2col+GEMM core in
``nn::gemm``). These fixtures pin both to the *Python* reference in
``kernels/ref.py`` — the same oracle the Pallas kernels and the AOT
artifacts are tested against — so the Rust and Python numerics can never
drift apart silently.

Inputs are deterministic (seeded ``numpy.random.RandomState``), cast to
float32 before entering the oracle, and serialized as plain JSON floats
(every f32 round-trips exactly through the f64 JSON number).

Run from the repo root:  python3 python/compile/export_golden.py
"""

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from kernels import ref  # noqa: E402

OUT_DIR = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", "rust", "tests", "golden"
)

def f32(rng, shape):
    return rng.uniform(-1.0, 1.0, size=shape).astype(np.float32)

def flat(a):
    return [float(v) for v in np.asarray(a, dtype=np.float32).reshape(-1)]

def conv_case(name, rng, cin, cout, hw, pad):
    x = f32(rng, (cin, hw, hw))
    k = f32(rng, (cout, cin, 3, 3))
    y = ref.conv2d_forward(x, k, pad=pad)
    assert y.shape == (cout, hw + 2 * pad - 2, hw + 2 * pad - 2), y.shape
    dy = f32(rng, y.shape)
    dx = ref.conv2d_input_grad(dy, k, pad=pad)
    assert dx.shape == x.shape, dx.shape
    dk = ref.conv2d_kernel_grad(dy, x, pad=pad, kh=3, kw=3)
    assert dk.shape == k.shape, dk.shape
    return {
        "name": name,
        "cin": cin,
        "cout": cout,
        "h": hw,
        "w": hw,
        "kh": 3,
        "kw": 3,
        "stride": 1,
        "pad": pad,
        "x": flat(x),
        "k": flat(k),
        "y": flat(y),
        "dy": flat(dy),
        "dx": flat(dx),
        "dk": flat(dk),
    }

def dense_case(name, rng, n_in, n_out, sparse_x):
    x = f32(rng, (n_in,))
    if sparse_x:  # post-ReLU-like input: the layers' real operating regime
        x = np.maximum(x, 0.0).astype(np.float32)
    w = f32(rng, (n_in, n_out))
    y = ref.dense_forward(x, w)
    dy = f32(rng, (n_out,))
    dx = ref.dense_input_grad(dy, w)
    dw = ref.dense_weight_grad(dy, x)
    return {
        "name": name,
        "n_in": n_in,
        "n_out": n_out,
        "x": flat(x),
        "w": flat(w),
        "y": flat(y),
        "dy": flat(dy),
        "dx": flat(dx),
        "dw": flat(dw),
    }

def model_case(name, rng, cin, hw, channels, classes):
    params = {
        "k1": f32(rng, (channels, cin, 3, 3)),
        "k2": f32(rng, (channels, channels, 3, 3)) * np.float32(0.5),
        "w": f32(rng, (channels * hw * hw, classes)) * np.float32(0.25),
    }
    x = f32(rng, (cin, hw, hw))
    logits = ref.model_forward(params, x)
    return {
        "name": name,
        "cin": cin,
        "image": hw,
        "channels": channels,
        "classes": classes,
        "k1": flat(params["k1"]),
        "k2": flat(params["k2"]),
        "w": flat(params["w"]),
        "x": flat(x),
        "logits": flat(logits),
    }

def main():
    os.makedirs(OUT_DIR, exist_ok=True)
    rng = np.random.RandomState(20240297)  # arXiv:2402.09780, reversed-ish

    conv = {
        "cases": [
            conv_case("conv_2to3_5x5_pad1", rng, 2, 3, 5, 1),
            conv_case("conv_1to1_4x4_pad0", rng, 1, 1, 4, 0),
            conv_case("conv_3to4_6x6_pad1", rng, 3, 4, 6, 1),
        ]
    }
    dense = {
        "cases": [
            dense_case("dense_12to4", rng, 12, 4, False),
            dense_case("dense_48to6_sparse", rng, 48, 6, True),
        ]
    }
    model = {"cases": [model_case("model_2ch_6px_c3_4cls", rng, 2, 6, 3, 4)]}

    for fname, payload in [
        ("conv.json", conv),
        ("dense.json", dense),
        ("model.json", model),
    ]:
        path = os.path.join(OUT_DIR, fname)
        with open(path, "w") as f:
            json.dump(payload, f)
        print(f"wrote {path} ({os.path.getsize(path)} bytes)")

if __name__ == "__main__":
    main()
