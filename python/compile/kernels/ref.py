"""Pure-jnp oracles for the six TinyCL computations (§III-F).

These are the ground truth the Pallas kernels (and, transitively, the AOT
artifacts the Rust runtime executes) are tested against. They mirror the
paper's equations directly:

* Eq. (1): conv forward          — ``conv2d_forward``
* Eq. (3): conv kernel gradient  — ``conv2d_kernel_grad``
* Eq. (2): conv gradient prop    — ``conv2d_input_grad``
* Eq. (4): dense forward         — ``dense_forward``
* Eq. (6): dense weight gradient — ``dense_weight_grad``
* Eq. (5): dense gradient prop   — ``dense_input_grad``

Conventions match the Rust f32 reference (`rust/src/nn/`): activations
CHW, kernels OIHW, dense weights (in, out), stride 1, zero padding that
preserves geometry (pad = (kh-1)//2), no biases, batch size 1.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_forward(x, k, pad=1):
    """Eq. (1): x (Cin,H,W) ⊛ k (Cout,Cin,Kh,Kw) → (Cout,H,W)."""
    out = lax.conv_general_dilated(
        x[None],  # NCHW
        k,  # OIHW
        window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def conv2d_input_grad(g, k, pad=1):
    """Eq. (2): gradient w.r.t. the input — full correlation of g with the
    spatially-flipped, io-transposed kernel (adjoint padding = Kh-1-pad,
    which reduces to `pad` for the geometry-preserving 3×3/pad-1 case)."""
    kt = jnp.flip(k, axis=(2, 3)).transpose(1, 0, 2, 3)  # (Cin,Cout,Kh,Kw)
    kh = k.shape[2]
    return conv2d_forward(g, kt, pad=kh - 1 - pad)


def conv2d_kernel_grad(g, x, pad=1, kh=None, kw=None):
    """Eq. (3): dK[o,i,dy,dx] = Σ_{h,w} g[o,h,w] · xpad[i,h+dy,w+dx].
    `kh`/`kw` default to the geometry-preserving 2·pad+1."""
    xpad = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    cout, h, w = g.shape
    cin = x.shape[0]
    kh = 2 * pad + 1 if kh is None else kh
    kw = 2 * pad + 1 if kw is None else kw
    taps = []
    for dy in range(kh):
        for dx in range(kw):
            window = lax.dynamic_slice(xpad, (0, dy, dx), (cin, h, w))
            # (Cout, H*W) @ (H*W, Cin) -> (Cout, Cin)
            taps.append(g.reshape(cout, -1) @ window.reshape(cin, -1).T)
    dk = jnp.stack(taps, axis=-1)  # (Cout, Cin, Kh*Kw)
    return dk.reshape(cout, cin, kh, kw)


def dense_forward(a, w):
    """Eq. (4): y = a · W with a (M,), W (M,N)."""
    return a @ w


def dense_input_grad(dy, w):
    """Eq. (5): dX = dY · Wᵀ."""
    return dy @ w.T


def dense_weight_grad(dy, a):
    """Eq. (6): dW = aᵀ · dY (outer product at batch 1)."""
    return jnp.outer(a, dy)


def relu(x):
    return jnp.maximum(x, 0.0)


def relu_grad(g, pre):
    return jnp.where(pre > 0, g, 0.0)


def masked_softmax_ce(logits, onehot, mask):
    """Cross-entropy over the active classes only (mask ∈ {0,1}^C); the
    paper's dense head has a dynamic class count (§III-F-4)."""
    neg = (1.0 - mask) * -1e9
    z = logits + neg
    z = z - jnp.max(z)
    logp = z - jnp.log(jnp.sum(mask * jnp.exp(z)) + 1e-30)
    loss = -jnp.sum(onehot * logp)
    probs = mask * jnp.exp(logp)
    dlogits = probs - onehot
    return loss, dlogits


def model_forward(params, x):
    """The paper's evaluation model: Conv+ReLU, Conv+ReLU, Dense."""
    k1, k2, w = params["k1"], params["k2"], params["w"]
    a1 = relu(conv2d_forward(x, k1))
    a2 = relu(conv2d_forward(a1, k2))
    return dense_forward(a2.reshape(-1), w)
