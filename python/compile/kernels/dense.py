"""Pallas dense-layer kernels (§III-F-4).

The ASIC runs the dense layer on the same 9 MACs with dynamic output
count (the CL head grows as classes arrive). The TPU restatement tiles
the *input* dimension M (8192 at the paper's geometry) over the grid and
accumulates into a single output block — mirroring the ASIC's partial-sum
register that survives across the input sweep:

* forward (Eq. 4):     y[N]  += a_m[km] @ W_m[km, N]   per input tile m
* input grad (Eq. 5):  dX_m[km] = W_m[km, N] @ dY[N]    per input tile m
* weight grad (Eq. 6): dW_m[km, N] = a_m[km] ⊗ dY[N]    per input tile m

VMEM per grid step at the paper's geometry (km=1024, N=10): W tile
1024×10×4B ≈ 40 KB + vectors — trivially resident. The head mask (the
dynamic class count) is applied by the caller, as in the ASIC where the
CU bounds the output counter (§III-F-4).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _k_block(m: int, preferred: int = 1024) -> int:
    """Largest divisor of ``m`` ≤ ``preferred``."""
    for b in range(min(preferred, m), 0, -1):
        if m % b == 0:
            return b
    return 1


def dense_forward(a, w, block_k=None):
    """Eq. (4): y = a @ W, input-tiled with an accumulating output block."""
    m, n = w.shape
    assert a.shape == (m,), f"a {a.shape} vs W {w.shape}"
    km = block_k or _k_block(m)

    def kernel(a_ref, w_ref, o_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += a_ref[...] @ w_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(m // km,),
        in_specs=[
            pl.BlockSpec((km,), lambda i: (i,)),
            pl.BlockSpec((km, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, w)


def dense_input_grad(dy, w, block_k=None):
    """Eq. (5): dX = dY @ Wᵀ, one input tile per grid step (the paper
    computes one dX pixel per MAC, iterating the partial-sum register)."""
    m, n = w.shape
    assert dy.shape == (n,)
    km = block_k or _k_block(m)

    def kernel(dy_ref, w_ref, o_ref):
        o_ref[...] = w_ref[...] @ dy_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(m // km,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((km, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((km,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), dy.dtype),
        interpret=True,
    )(dy, w)


def dense_weight_grad(dy, a, block_k=None):
    """Eq. (6): dW = a ⊗ dY, outer product tiled over the input dim (the
    paper's multi-adder mode: 64 products accumulated per cycle)."""
    (m,) = a.shape
    (n,) = dy.shape
    km = block_k or _k_block(m)

    def kernel(dy_ref, a_ref, o_ref):
        o_ref[...] = a_ref[...][:, None] * dy_ref[...][None, :]

    return pl.pallas_call(
        kernel,
        grid=(m // km,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((km,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((km, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(dy, a)


@jax.custom_vjp
def dense(a, w):
    """Differentiable dense layer whose forward and backward are the
    Pallas kernels above."""
    return dense_forward(a, w)


def _dense_vjp_fwd(a, w):
    return dense_forward(a, w), (a, w)


def _dense_vjp_bwd(res, dy):
    a, w = res
    return dense_input_grad(dy, w), dense_weight_grad(dy, a)


dense.defvjp(_dense_vjp_fwd, _dense_vjp_bwd)
