"""Pallas convolution kernels — the TPU re-think of TinyCL's datapath
(DESIGN.md §Hardware-Adaptation).

The ASIC computes one output pixel per cycle from a 9-tap × 8-channel
window held in registers, with a snake traversal keeping 6/9 window
columns resident. On a TPU the analogous resource is the MXU, so each
kernel restates the paper's computation as **9 tap-matmuls accumulated in
VMEM** instead of 9 MACs accumulated in a Dadda tree:

* forward (Eq. 1):    out[hw, co] = Σ_t xpad_t[hw, ci] @ K_t[ci, co]
* input grad (Eq. 2): same dataflow with the io-transposed, spatially
                      flipped kernel — exactly the paper's observation
                      that "the data flow is the same as for the forward
                      propagation" (§III-F-3);
* kernel grad (Eq. 3): dK_t[co, ci] = G[co, hw] @ xpad_t[ci, hw]ᵀ, one
                      tap per grid step — the paper's MAC-per-tap
                      indexing (Eq. 7) becomes a grid axis.

Row-block tiling: the output is tiled over row blocks (grid axis), the
padded input is passed whole; each grid step's 9 tap windows overlap the
next step's by 2 rows — the snake-reuse halo, kept in VMEM. VMEM per
step at the paper's geometry (Cin=8, 32×32, block=8 rows, Cout=8):
xpad 8×34×34×4B ≈ 36 KB + kmat 9×8×8×4B ≈ 2 KB + acc 8·32×8×4B ≈ 8 KB —
far under the ~16 MB VMEM budget; the block factor exists to keep the
schedule shaped like the ASIC's row sweep, not to fit memory.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
the Rust runtime loads (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_block(h: int, preferred: int = 8) -> int:
    """Largest divisor of ``h`` that is ≤ ``preferred`` (grid must tile
    the row axis exactly)."""
    for b in range(min(preferred, h), 0, -1):
        if h % b == 0:
            return b
    return 1


def conv2d_forward(x, k, pad=1, block_rows=None):
    """Eq. (1) as 9 accumulated tap-matmuls. x (Cin,H,W), k (Cout,Cin,Kh,Kw)
    → (Cout,H,W). Stride 1, geometry-preserving zero padding."""
    cin, h, w = x.shape
    cout, kcin, kh, kw = k.shape
    assert kcin == cin, f"kernel cin {kcin} != input cin {cin}"
    assert kh == kw == 2 * pad + 1, "geometry-preserving padding only"
    taps = kh * kw
    br = block_rows or _row_block(h)

    xpad = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    # (taps, Cin, Cout): one MXU operand per tap.
    kmat = k.reshape(cout, cin, taps).transpose(2, 1, 0)

    def kernel(xpad_ref, kmat_ref, o_ref):
        r = pl.program_id(0)
        acc = jnp.zeros((br * w, cout), dtype=jnp.float32)
        for t in range(taps):  # unrolled: taps is a static 9
            dy, dx = divmod(t, kw)
            window = xpad_ref[:, pl.ds(r * br + dy, br), pl.ds(dx, w)]
            acc += window.reshape(cin, br * w).T @ kmat_ref[t]
        o_ref[...] = acc.astype(o_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(h // br,),
        in_specs=[
            pl.BlockSpec(xpad.shape, lambda r: (0, 0, 0)),
            pl.BlockSpec(kmat.shape, lambda r: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((br * w, cout), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((h * w, cout), x.dtype),
        interpret=True,
    )(xpad, kmat)
    return out.T.reshape(cout, h, w)


def conv2d_input_grad(g, k, pad=1, block_rows=None):
    """Eq. (2): dV = g ⊛ flip(k)ᵀ — same kernel, transformed operand.
    Adjoint padding is Kh-1-pad (== pad for the geometry-preserving
    3×3/pad-1 case), matching ``ref.conv2d_input_grad``."""
    kt = jnp.flip(k, axis=(2, 3)).transpose(1, 0, 2, 3)
    kh = k.shape[2]
    return conv2d_forward(g, kt, pad=kh - 1 - pad, block_rows=block_rows)


def conv2d_kernel_grad(g, x, pad=1):
    """Eq. (3): one tap per grid step (the paper's Eq. 7 MAC indexing);
    each step is a (Cout, HW) × (HW, Cin) MXU contraction."""
    cout, h, w = g.shape
    cin = x.shape[0]
    kh = kw = 2 * pad + 1
    taps = kh * kw

    xpad = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    gmat = g.reshape(cout, h * w)

    def kernel(g_ref, xpad_ref, o_ref):
        t = pl.program_id(0)
        dy = t // kw
        dx = t % kw
        window = xpad_ref[:, pl.ds(dy, h), pl.ds(dx, w)]
        o_ref[0] = g_ref[...] @ window.reshape(cin, h * w).T

    dk = pl.pallas_call(
        kernel,
        grid=(taps,),
        in_specs=[
            pl.BlockSpec(gmat.shape, lambda t: (0, 0)),
            pl.BlockSpec(xpad.shape, lambda t: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, cout, cin), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((taps, cout, cin), g.dtype),
        interpret=True,
    )(gmat, xpad)
    return dk.transpose(1, 2, 0).reshape(cout, cin, kh, kw)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def conv2d(x, k, pad=1):
    """Differentiable conv whose forward *and* backward are the Pallas
    kernels above — the model's train-step HLO therefore contains exactly
    the paper's six computations."""
    return conv2d_forward(x, k, pad=pad)


def _conv2d_vjp_fwd(x, k, pad):
    return conv2d_forward(x, k, pad=pad), (x, k)


def _conv2d_vjp_bwd(pad, res, g):
    x, k = res
    return conv2d_input_grad(g, k, pad=pad), conv2d_kernel_grad(g, x, pad=pad)


conv2d.defvjp(_conv2d_vjp_fwd, _conv2d_vjp_bwd)
