# L1: Pallas kernels for the paper's six computations (+ pure-jnp oracle).
from . import conv, dense, ref  # noqa: F401
