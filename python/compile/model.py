"""L2: the paper's evaluation model in JAX, built on the Pallas kernels.

Conv3×3(3→8) + ReLU + Conv3×3(8→8) + ReLU + Dense(8·H·W → C), batch 1,
SGD, masked softmax-CE head (the CL head's class count is dynamic, so the
AOT signature takes a {0,1} mask instead of a class count — §III-F-4).

Both entry points are pure functions over flat argument lists so the Rust
runtime can feed PJRT literals positionally:

* ``forward(k1, k2, w, x) -> (logits,)``
* ``train_step(k1, k2, w, x, onehot, mask, lr) ->
        (k1', k2', w', loss, logits)``

Because ``conv2d`` / ``dense`` carry custom VJPs that are themselves
Pallas kernels, the lowered train-step HLO contains exactly the paper's
six computations — forward ×2 conv + dense, gradient propagation ×2,
kernel/weight gradients ×3 — not XLA's generic conv backward.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.conv import conv2d
from .kernels.dense import dense


@dataclass(frozen=True)
class ModelConfig:
    """Mirror of ``rust/src/nn/model.rs::ModelConfig`` (keep in sync)."""

    in_channels: int = 3
    image_size: int = 32
    conv_channels: int = 8
    num_classes: int = 10

    @property
    def dense_in(self) -> int:
        return self.conv_channels * self.image_size * self.image_size

    def shapes(self):
        """Shapes of (k1, k2, w, x, onehot, mask, lr)."""
        c, s = self.conv_channels, self.image_size
        return {
            "k1": (c, self.in_channels, 3, 3),
            "k2": (c, c, 3, 3),
            "w": (self.dense_in, self.num_classes),
            "x": (self.in_channels, s, s),
            "onehot": (self.num_classes,),
            "mask": (self.num_classes,),
            "lr": (),
        }


PAPER = ModelConfig()
# Small geometry used by fast Rust integration tests
# (mirror of the Rust tests' `tiny_config`).
TINY = ModelConfig(in_channels=3, image_size=8, conv_channels=4, num_classes=4)


def forward(k1, k2, w, x):
    """Inference: logits over all classes (masking is the caller's)."""
    a1 = jax.nn.relu(conv2d(x, k1))
    a2 = jax.nn.relu(conv2d(a1, k2))
    return (dense(a2.reshape(-1), w),)


def _loss_fn(params, x, onehot, mask):
    k1, k2, w = params
    (logits,) = forward(k1, k2, w, x)
    # Masked softmax-CE: inactive classes get -1e9 before the softmax and
    # zero probability after (matches rust/src/nn/loss.rs).
    z = logits + (1.0 - mask) * -1e9
    z = z - jnp.max(z)
    logp = z - jnp.log(jnp.sum(mask * jnp.exp(z)) + 1e-30)
    return -jnp.sum(onehot * logp), logits


def train_step(k1, k2, w, x, onehot, mask, lr):
    """One batch-1 SGD step; returns updated params, loss, logits."""
    (loss, logits), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
        (k1, k2, w), x, onehot, mask
    )
    dk1, dk2, dw = grads
    return (k1 - lr * dk1, k2 - lr * dk2, w - lr * dw, loss, logits)


def example_args(cfg: ModelConfig):
    """ShapeDtypeStructs for AOT lowering, in positional order."""
    s = cfg.shapes()
    f32 = jnp.float32
    spec = lambda name: jax.ShapeDtypeStruct(s[name], f32)  # noqa: E731
    return {
        "forward": tuple(spec(n) for n in ("k1", "k2", "w", "x")),
        "train_step": tuple(
            spec(n) for n in ("k1", "k2", "w", "x", "onehot", "mask", "lr")
        ),
    }
