"""Differential oracle for the obs log2 latency histogram
(rust/src/obs/hist.rs). Pure-python, no third-party deps: runnable
standalone (``python3 python/tests/test_histogram.py``) or under pytest.

The two suites pin the same convention with shared constants:

* bucketing: ``bucket_index(v) = 0`` if ``v == 0`` else
  ``min(floor(log2(v)) + 1, NBUCKETS - 1)`` — bucket 0 holds exactly 0,
  bucket i >= 1 holds ``[2^(i-1), 2^i)``, the last bucket overflows.
* the stream ``(i*i) % 65521`` for ``i in range(1000)``, quantiles
  0.5 / 0.95 / 0.99 — mirrored by the Rust unit test
  ``mean_is_exact_and_quantile_within_a_factor_of_two``.
* error bounds: means are **exact** (the sum/count side-channels are not
  bucket-derived); a quantile estimate lands inside the true value's
  bucket, hence within a factor of 2 of the truth.
* merging is lossless with respect to the representation: merging two
  snapshots equals one snapshot of the union stream, so merged quantiles
  equal union quantiles — the reason ``LatencySummary`` merges
  histograms and never averages percentiles.
"""

import math

NBUCKETS = 40

# The shared fixed stream, and the quantiles both suites probe.
STREAM = [(i * i) % 65_521 for i in range(1000)]
QUANTILES = (0.5, 0.95, 0.99)


def bucket_index(v):
    """Mirror of rust ``obs::hist::bucket_index`` (for v >= 0).

    ``int.bit_length`` is ``floor(log2(v)) + 1``, the same value the
    Rust side computes as ``64 - leading_zeros``.
    """
    if v == 0:
        return 0
    return min(v.bit_length(), NBUCKETS - 1)


def bucket_lo(i):
    return 0 if i == 0 else 1 << (i - 1)


def bucket_hi(i):
    return 1 << i


class Snapshot:
    """Mirror of rust ``obs::hist::HistSnapshot``."""

    def __init__(self):
        self.buckets = [0] * NBUCKETS
        self.count = 0
        self.sum = 0
        self.max = 0

    @classmethod
    def of(cls, values):
        s = cls()
        for v in values:
            s.buckets[bucket_index(v)] += 1
            s.count += 1
            s.sum += v
            s.max = max(s.max, v)
        return s

    def merge(self, other):
        for i, b in enumerate(other.buckets):
            self.buckets[i] += b
        self.count += other.count
        self.sum += other.sum
        self.max = max(self.max, other.max)

    def mean(self):
        return 0.0 if self.count == 0 else self.sum / self.count

    def quantile(self, q):
        """Mirror of ``HistSnapshot::quantile_us``: nearest-rank bucket
        with linear in-bucket interpolation by rank position, clamped to
        the exact max."""
        if self.count == 0:
            return 0.0
        rank = min(max(math.ceil(q * self.count), 1), self.count)
        seen = 0
        for i in range(NBUCKETS):
            n = self.buckets[i]
            if n == 0:
                continue
            if seen + n >= rank:
                lo = float(bucket_lo(i))
                hi = min(float(bucket_hi(i)), float(max(self.max, 1)))
                frac = (rank - seen) / n
                return min(lo + (hi - lo) * frac, float(self.max))
            seen += n
        return float(self.max)


def test_bucket_boundaries_match_the_rust_constants():
    # The exact pins of rust `bucket_index_boundaries`.
    assert bucket_index(0) == 0
    assert bucket_index(1) == 1
    assert bucket_index(2) == 2
    assert bucket_index(3) == 2
    assert bucket_index(4) == 3
    assert bucket_index(1023) == 10
    assert bucket_index(1024) == 11
    assert bucket_index(2**64 - 1) == NBUCKETS - 1
    for i in range(1, NBUCKETS - 1):
        assert bucket_index(bucket_lo(i)) == i
        assert bucket_index(bucket_hi(i) - 1) == i


def test_mean_is_exact_and_quantiles_are_bucket_bounded():
    snap = Snapshot.of(STREAM)
    assert snap.count == 1000
    assert snap.sum == sum(STREAM)
    assert abs(snap.mean() - sum(STREAM) / 1000.0) < 1e-9

    truth_sorted = sorted(STREAM)
    for q in QUANTILES:
        rank = min(max(math.ceil(q * 1000), 1), 1000)
        truth = float(truth_sorted[rank - 1])
        est = snap.quantile(q)
        # Factor-of-2 relative bound …
        assert est / max(truth, 1.0) <= 2.0, f"q={q}: {est} vs {truth}"
        assert truth / max(est, 1.0) <= 2.0, f"q={q}: {est} vs {truth}"
        # … via the sharper claim: the estimate never leaves the true
        # value's bucket.
        bi = bucket_index(int(truth))
        assert bucket_lo(bi) <= est <= bucket_hi(bi), f"q={q}: {est} left bucket {bi}"
    assert snap.quantile(1.0) == float(snap.max)


def test_merge_is_lossless_so_percentiles_are_never_averaged():
    a, b = STREAM[:500], STREAM[500:]
    merged = Snapshot.of(a)
    merged.merge(Snapshot.of(b))
    union = Snapshot.of(STREAM)
    assert merged.buckets == union.buckets
    assert (merged.count, merged.sum, merged.max) == (union.count, union.sum, union.max)
    # Merge-then-quantile equals quantile-of-the-union — bit-for-bit,
    # which averaging two per-shard p99s would not be.
    for q in QUANTILES:
        assert merged.quantile(q) == union.quantile(q)
    assert merged.mean() == union.mean()


def test_empty_and_single_value_edges():
    empty = Snapshot.of([])
    assert empty.mean() == 0.0
    assert empty.quantile(0.99) == 0.0
    one = Snapshot.of([42])
    for q in QUANTILES:
        est = one.quantile(q)
        assert 32.0 <= est <= 42.0  # inside [2^5, 2^6), clamped to max
    assert one.quantile(1.0) == 42.0


if __name__ == "__main__":
    test_bucket_boundaries_match_the_rust_constants()
    test_mean_is_exact_and_quantiles_are_bucket_bounded()
    test_merge_is_lossless_so_percentiles_are_never_averaged()
    test_empty_and_single_value_edges()
    print("log2-histogram differential: OK")
