"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (and a bfloat16 smoke) so the kernels are
correct for *any* geometry, not just the paper's — the Rust coordinator
sweeps model geometry in the design-space benches.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, dense, ref

jax.config.update("jax_platform_name", "cpu")

# Shape strategies: small enough that a hypothesis sweep stays fast under
# interpret mode, wide enough to hit odd sizes (non-divisible row blocks,
# single channels, single pixels).
dims = st.integers(min_value=1, max_value=6)
sizes = st.integers(min_value=3, max_value=12)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def assert_close(got, want, rtol=1e-5, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)


class TestConvForward:
    @settings(max_examples=25, deadline=None)
    @given(cin=dims, cout=dims, h=sizes, w=sizes, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, cin, cout, h, w, seed):
        kx, kk = keys(seed, 2)
        x = rand(kx, (cin, h, w))
        k = rand(kk, (cout, cin, 3, 3))
        assert_close(conv.conv2d_forward(x, k), ref.conv2d_forward(x, k))

    def test_paper_geometry(self):
        kx, kk = keys(0, 2)
        x = rand(kx, (8, 32, 32))
        k = rand(kk, (8, 8, 3, 3))
        assert_close(conv.conv2d_forward(x, k), ref.conv2d_forward(x, k))

    @pytest.mark.parametrize("block_rows", [1, 2, 4, 8, 16, 32])
    def test_block_size_invariant(self, block_rows):
        kx, kk = keys(1, 2)
        x = rand(kx, (3, 32, 32))
        k = rand(kk, (8, 3, 3, 3))
        assert_close(
            conv.conv2d_forward(x, k, block_rows=block_rows),
            ref.conv2d_forward(x, k),
        )

    def test_identity_kernel(self):
        # A centered delta kernel must reproduce the input exactly.
        x = rand(keys(2, 1)[0], (2, 8, 8))
        k = jnp.zeros((2, 2, 3, 3)).at[0, 0, 1, 1].set(1.0).at[1, 1, 1, 1].set(1.0)
        assert_close(conv.conv2d_forward(x, k), x)

    def test_bf16_smoke(self):
        kx, kk = keys(3, 2)
        x = rand(kx, (4, 8, 8), dtype=jnp.bfloat16)
        k = rand(kk, (4, 4, 3, 3), dtype=jnp.bfloat16)
        got = conv.conv2d_forward(x, k).astype(jnp.float32)
        want = ref.conv2d_forward(x, k).astype(jnp.float32)
        assert_close(got, want, rtol=0.1, atol=0.1)


class TestConvInputGrad:
    @settings(max_examples=25, deadline=None)
    @given(cin=dims, cout=dims, h=sizes, w=sizes, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, cin, cout, h, w, seed):
        kg, kk = keys(seed, 2)
        g = rand(kg, (cout, h, w))
        k = rand(kk, (cout, cin, 3, 3))
        assert_close(conv.conv2d_input_grad(g, k), ref.conv2d_input_grad(g, k))

    def test_matches_jax_autodiff(self):
        # The pallas backward must equal jax's own vjp of the forward.
        kx, kk, kg = keys(4, 3)
        x = rand(kx, (3, 8, 8))
        k = rand(kk, (5, 3, 3, 3))
        g = rand(kg, (5, 8, 8))
        _, vjp = jax.vjp(lambda x_: ref.conv2d_forward(x_, k), x)
        assert_close(conv.conv2d_input_grad(g, k), vjp(g)[0], rtol=1e-4, atol=1e-5)


class TestConvKernelGrad:
    @settings(max_examples=25, deadline=None)
    @given(cin=dims, cout=dims, h=sizes, w=sizes, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, cin, cout, h, w, seed):
        kg, kx = keys(seed, 2)
        g = rand(kg, (cout, h, w))
        x = rand(kx, (cin, h, w))
        assert_close(
            conv.conv2d_kernel_grad(g, x), ref.conv2d_kernel_grad(g, x), rtol=1e-4, atol=1e-4
        )

    def test_matches_jax_autodiff(self):
        kx, kk, kg = keys(5, 3)
        x = rand(kx, (3, 8, 8))
        k = rand(kk, (5, 3, 3, 3))
        g = rand(kg, (5, 8, 8))
        _, vjp = jax.vjp(lambda k_: ref.conv2d_forward(x, k_), k)
        assert_close(conv.conv2d_kernel_grad(g, x), vjp(g)[0], rtol=1e-4, atol=1e-5)


class TestDense:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=600),
        n=st.integers(min_value=1, max_value=16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_forward_matches_ref(self, m, n, seed):
        ka, kw = keys(seed, 2)
        a = rand(ka, (m,))
        w = rand(kw, (m, n))
        assert_close(dense.dense_forward(a, w), ref.dense_forward(a, w), rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=600),
        n=st.integers(min_value=1, max_value=16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_input_grad_matches_ref(self, m, n, seed):
        kd, kw = keys(seed, 2)
        dy = rand(kd, (n,))
        w = rand(kw, (m, n))
        assert_close(dense.dense_input_grad(dy, w), ref.dense_input_grad(dy, w), rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=600),
        n=st.integers(min_value=1, max_value=16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_weight_grad_matches_ref(self, m, n, seed):
        kd, ka = keys(seed, 2)
        dy = rand(kd, (n,))
        a = rand(ka, (m,))
        assert_close(dense.dense_weight_grad(dy, a), ref.dense_weight_grad(dy, a))

    def test_paper_geometry(self):
        ka, kw = keys(6, 2)
        a = rand(ka, (8192,))
        w = rand(kw, (8192, 10))
        assert_close(dense.dense_forward(a, w), ref.dense_forward(a, w), rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("block_k", [1, 7, 64, 512])
    def test_block_size_invariant(self, block_k):
        ka, kw = keys(7, 2)
        m = 512 if 512 % block_k == 0 else 7 * 64
        a = rand(ka, (m,))
        w = rand(kw, (m, 8))
        if m % block_k:
            pytest.skip("block must divide m")
        assert_close(
            dense.dense_forward(a, w, block_k=block_k),
            ref.dense_forward(a, w),
            rtol=1e-4,
            atol=1e-4,
        )


class TestCustomVjp:
    def test_conv2d_grad_is_pallas_backward(self):
        kx, kk, kg = keys(8, 3)
        x = rand(kx, (3, 8, 8))
        k = rand(kk, (4, 3, 3, 3))
        g = rand(kg, (4, 8, 8))
        _, vjp = jax.vjp(lambda x_, k_: conv.conv2d(x_, k_), x, k)
        dx, dk = vjp(g)
        assert_close(dx, ref.conv2d_input_grad(g, k), rtol=1e-4, atol=1e-5)
        assert_close(dk, ref.conv2d_kernel_grad(g, x), rtol=1e-4, atol=1e-4)

    def test_dense_grad_is_pallas_backward(self):
        ka, kw, kg = keys(9, 3)
        a = rand(ka, (96,))
        w = rand(kw, (96, 5))
        g = rand(kg, (5,))
        _, vjp = jax.vjp(dense.dense, a, w)
        da, dw = vjp(g)
        assert_close(da, ref.dense_input_grad(g, w), rtol=1e-4, atol=1e-5)
        assert_close(dw, ref.dense_weight_grad(g, a))
