"""Differential oracle for the serving subsystem's coordinated-omission
latency correction (rust/src/serve/loadgen.rs).

Pure-python, no third-party deps: runnable standalone
(``python3 python/tests/test_coordinated_omission.py``) or under pytest.

The model: a fixed open-loop arrival schedule hits a single FIFO server
with a known constant service time. Completion times follow the textbook
recurrence ``done_i = max(arrival_i, done_{i-1}) + service``. The
**corrected** latency of request *i* is ``done_i - arrival_i`` — time
from *intended* arrival, charging every microsecond the request spent
queued. The **uncorrected** view ("measure from whenever the generator
could send", i.e. when the server freed up) reports a flat ``service``
for every request — the coordinated omission the correction exists to
expose.

Percentiles use the same linear interpolation as the Rust
``util::stats::percentile_sorted``. The constants asserted here are the
exact values ``rust/src/serve/loadgen.rs`` pins in
``coordinated_omission_correction_matches_python_differential`` — the
two suites must agree on the same numbers or one of them drifted.
"""

# The shared fixed case: arrivals every 100 µs, service 150 µs, n = 20.
ARRIVAL_GAP_US = 100
SERVICE_US = 150
N = 20

# Constants pinned on both sides of the differential.
EXPECTED = {
    "p50": 625.0,
    "p95": 1052.5,
    "p99": 1090.5,
    "max": 1100.0,
    "mean": 625.0,
}


def percentile_sorted(sorted_v, pct):
    """Mirror of rust `util::stats::percentile_sorted` (linear
    interpolation over a pre-sorted list)."""
    assert sorted_v, "empty sample set"
    assert 0.0 <= pct <= 100.0
    if len(sorted_v) == 1:
        return sorted_v[0]
    rank = pct / 100.0 * (len(sorted_v) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(sorted_v) - 1)
    frac = rank - lo
    return sorted_v[lo] * (1.0 - frac) + sorted_v[hi] * frac


def fifo_completions(arrivals, service):
    done, prev = [], 0
    for a in arrivals:
        t = max(a, prev) + service
        done.append(t)
        prev = t
    return done


def corrected_latencies(arrivals, completions):
    return [c - a for a, c in zip(arrivals, completions)]


def test_corrected_percentiles_match_the_rust_constants():
    arrivals = [ARRIVAL_GAP_US * i for i in range(1, N + 1)]
    completions = fifo_completions(arrivals, SERVICE_US)
    lat = sorted(corrected_latencies(arrivals, completions))
    # The saturated FIFO makes the backlog, and thus the corrected
    # latency, grow linearly: 150, 200, 250, … 1100.
    assert lat == list(range(150, 1101, 50))
    got = {
        "p50": percentile_sorted(lat, 50.0),
        "p95": percentile_sorted(lat, 95.0),
        "p99": percentile_sorted(lat, 99.0),
        "max": float(lat[-1]),
        "mean": sum(lat) / len(lat),
    }
    for key, want in EXPECTED.items():
        assert abs(got[key] - want) < 1e-9, f"{key}: {got[key]} != {want}"


def test_uncorrected_view_hides_the_queueing():
    """The omission itself: measured from actual send (= when the server
    freed up), every request looks like a flat `service` — p50 and p99
    collapse to 150 µs while the corrected p50 is 625 µs."""
    arrivals = [ARRIVAL_GAP_US * i for i in range(1, N + 1)]
    completions = fifo_completions(arrivals, SERVICE_US)
    sends = [max(a, prev) for a, prev in zip(arrivals, [0] + completions[:-1])]
    naive = [c - s for s, c in zip(sends, completions)]
    assert all(v == SERVICE_US for v in naive)
    assert percentile_sorted(sorted(naive), 50.0) == SERVICE_US
    # The corrected distribution is a different world.
    corrected = sorted(corrected_latencies(arrivals, completions))
    assert percentile_sorted(corrected, 50.0) / SERVICE_US > 4.0


def test_percentile_edge_cases_match_rust_hardening():
    """Mirrors the `LatencySummary` edge cases the Rust side unit-tests:
    single sample and all-ties collapse every percentile to the value."""
    assert percentile_sorted([42.0], 50.0) == 42.0
    assert percentile_sorted([42.0], 99.0) == 42.0
    tied = [7.0] * 9
    for pct in (0.0, 50.0, 95.0, 99.0, 100.0):
        assert percentile_sorted(tied, pct) == 7.0
    two = [100.0, 200.0]
    assert percentile_sorted(two, 50.0) == 150.0
    assert percentile_sorted(two, 100.0) == 200.0


if __name__ == "__main__":
    test_corrected_percentiles_match_the_rust_constants()
    test_uncorrected_view_hides_the_queueing()
    test_percentile_edge_cases_match_rust_hardening()
    print("coordinated-omission differential: OK")
    print("expected constants:", EXPECTED)
