"""L2 correctness: the JAX model (shapes, loss semantics, train-step
behaviour) and the AOT lowering path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def init_params(cfg: model.ModelConfig, seed=0):
    s = cfg.shapes()
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    scale = lambda sh: (2.0 / np.prod(sh[1:])) ** 0.5  # noqa: E731
    return tuple(
        (jax.random.normal(kk, s[n]) * scale(s[n])).astype(jnp.float32)
        for kk, n in zip(k, ("k1", "k2", "w"))
    )


def sample_inputs(cfg: model.ModelConfig, label=1, active=4, seed=3):
    x = jax.random.normal(jax.random.PRNGKey(seed), cfg.shapes()["x"]).astype(jnp.float32)
    onehot = jnp.zeros(cfg.num_classes).at[label].set(1.0)
    mask = (jnp.arange(cfg.num_classes) < active).astype(jnp.float32)
    return x, onehot, mask


class TestForward:
    def test_matches_pure_jnp_model(self):
        cfg = model.TINY
        k1, k2, w = init_params(cfg)
        x, _, _ = sample_inputs(cfg)
        (logits,) = model.forward(k1, k2, w, x)
        want = ref.model_forward({"k1": k1, "k2": k2, "w": w}, x)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_paper_shapes(self):
        cfg = model.PAPER
        assert cfg.dense_in == 8192
        k1, k2, w = init_params(cfg)
        x, _, _ = sample_inputs(cfg)
        (logits,) = model.forward(k1, k2, w, x)
        assert logits.shape == (10,)


class TestTrainStep:
    def test_loss_decreases_on_repeated_sample(self):
        cfg = model.TINY
        params = init_params(cfg)
        x, onehot, mask = sample_inputs(cfg)
        step = jax.jit(model.train_step)
        losses = []
        for _ in range(10):
            *params, loss, _ = step(*params, x, onehot, mask, jnp.float32(0.1))
            params = tuple(params)
            losses.append(float(loss))
        assert losses[-1] < 0.5 * losses[0], losses

    def test_masked_classes_get_no_gradient(self):
        # With the mask restricted to classes {0,1}, rows of W feeding
        # classes 2..N must not change.
        cfg = model.TINY
        params = init_params(cfg)
        x, onehot, mask = sample_inputs(cfg, label=1, active=2)
        k1n, k2n, wn, _, _ = model.train_step(*params, x, onehot, mask, jnp.float32(0.5))
        w_before = np.asarray(params[2])
        w_after = np.asarray(wn)
        np.testing.assert_array_equal(w_before[:, 2:], w_after[:, 2:])
        assert np.abs(w_after[:, :2] - w_before[:, :2]).max() > 0

    def test_loss_is_masked_ce(self):
        cfg = model.TINY
        params = init_params(cfg)
        x, onehot, mask = sample_inputs(cfg, label=0, active=2)
        *_, loss, logits = model.train_step(*params, x, onehot, mask, jnp.float32(0.0))
        want, _ = ref.masked_softmax_ce(logits, onehot, mask)
        np.testing.assert_allclose(float(loss), float(want), rtol=1e-5)

    def test_zero_lr_keeps_params(self):
        cfg = model.TINY
        params = init_params(cfg)
        x, onehot, mask = sample_inputs(cfg)
        k1n, k2n, wn, _, _ = model.train_step(*params, x, onehot, mask, jnp.float32(0.0))
        for old, new in zip(params, (k1n, k2n, wn)):
            np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


class TestAot:
    @pytest.mark.parametrize("cfg", [model.TINY], ids=["tiny"])
    def test_lowering_produces_parseable_hlo(self, cfg):
        hlo = aot.lower_all(cfg)
        for name, text in hlo.items():
            assert text.startswith("HloModule"), f"{name} is not HLO text"
            assert "ENTRY" in text

    def test_forward_hlo_has_four_params(self):
        hlo = aot.lower_all(model.TINY)["forward"]
        # k1, k2, w, x — parameter count is the rust runtime's contract.
        for i in range(4):
            assert f"parameter({i})" in hlo
        assert "parameter(4)" not in hlo

    def test_train_step_hlo_has_seven_params(self):
        hlo = aot.lower_all(model.TINY)["train_step"]
        for i in range(7):
            assert f"parameter({i})" in hlo
        assert "parameter(7)" not in hlo

    def test_source_hash_is_stable(self):
        assert aot.source_hash() == aot.source_hash()
