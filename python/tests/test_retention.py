"""Differential oracle for the continual-learning retention metrics and
the multi-task stream generators (rust/src/cl/metrics.rs,
rust/src/data/synthetic.rs).

Pure-python, no third-party deps: runnable standalone
(``python3 python/tests/test_retention.py``) or under pytest.

Two halves, both pinned against the exact constants the Rust unit
tests assert, so the suites must agree on the same numbers or one of
them drifted:

* **Accuracy-matrix math** — ``R[i][j]`` is accuracy on task *j* after
  training task *i* (lower-triangular, filled row by row). Per-task
  final accuracy is the last row; forgetting for task *j < T-1* is
  ``max_{j<=i<T-1} R[i][j] - R[T-1][j]`` (the last task contributes 0);
  backward transfer is ``R[T-1][j] - R[j][j]``; retention is
  ``R[T-1][j] / max_{j<=i<=T-1} R[i][j]`` with the 0/0 case defined as
  1.0 (nothing learned => nothing forgotten). The aggregates are the
  means over the first T-1 tasks. Degenerate single-task and all-zero
  matrices are covered explicitly.

* **Stream generators** — ``splitmix64``, the Fisher-Yates
  class-partition shuffle, and the three task schedules (roundrobin /
  blocked / random) mirrored constant-for-constant: same seed => same
  schedule, partitions are disjoint and exhaustive, and every schedule
  position is addressable without generating its prefix.
"""

MASK = (1 << 64) - 1


# ---- accuracy-matrix math (mirror of cl::metrics) --------------------

def accuracy_per_task(r):
    return list(r[-1])


def forgetting_per_task(r):
    t = len(r)
    last = r[-1]
    out = []
    for j in range(t):
        if j + 1 >= t:
            out.append(0.0)
            continue
        best = max(r[i][j] for i in range(j, t - 1))
        out.append(best - last[j])
    return out


def backward_transfer_per_task(r):
    t = len(r)
    last = r[-1]
    return [last[j] - r[j][j] if j + 1 < t else 0.0 for j in range(t)]


def retention_per_task(r):
    t = len(r)
    last = r[-1]
    out = []
    for j in range(t):
        best = max(r[i][j] for i in range(j, t))
        out.append(1.0 if best == 0.0 else last[j] / best)
    return out


def forgetting(r):
    t = len(r)
    if t < 2:
        return 0.0
    return sum(forgetting_per_task(r)[: t - 1]) / (t - 1)


def backward_transfer(r):
    t = len(r)
    if t < 2:
        return 0.0
    return sum(backward_transfer_per_task(r)[: t - 1]) / (t - 1)


def final_average(r):
    return sum(r[-1]) / len(r[-1])


# ---- stream generators (mirror of data::synthetic) -------------------

def splitmix64(seed):
    z = (seed + 0x9E37_79B9_7F4A_7C15) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK
    return z ^ (z >> 31)


def task_class_partition(num_classes, num_tasks, seed):
    assert 0 < num_tasks <= num_classes
    classes = list(range(num_classes))
    for i in range(num_classes - 1, 0, -1):
        j = splitmix64(seed ^ i) % (i + 1)
        classes[i], classes[j] = classes[j], classes[i]
    base, extra = divmod(num_classes, num_tasks)
    parts, at = [], 0
    for t in range(num_tasks):
        take = base + (1 if t < extra else 0)
        parts.append(classes[at:at + take])
        at += take
    return parts


def task_for(schedule, i, n, k, seed):
    assert k > 0
    if schedule == "roundrobin":
        return i % k
    if schedule == "blocked":
        return 0 if n == 0 else min((i * k) // n, k - 1)
    if schedule == "random":
        h = splitmix64(seed ^ ((i * 0xD6E8_FEB8_6659_FD93) & MASK))
        return h % k
    raise ValueError(schedule)


# ---- tests -----------------------------------------------------------

def assert_close(a, b, what):
    assert abs(a - b) < 1e-12, f"{what}: {a} vs {b}"


def test_perfect_memory_no_forgetting():
    r = [[0.9], [0.9, 0.8], [0.9, 0.8, 0.85]]
    assert_close(final_average(r), 0.85, "final_average")
    assert backward_transfer(r) == 0.0
    assert forgetting(r) == 0.0
    assert retention_per_task(r) == [1.0, 1.0, 1.0]


def test_catastrophic_forgetting_detected():
    r = [[0.95], [0.10, 0.95]]
    assert backward_transfer(r) < -0.8
    assert forgetting(r) > 0.8
    assert_close(forgetting_per_task(r)[0], 0.85, "forgetting[0]")
    assert_close(retention_per_task(r)[0], 0.10 / 0.95, "retention[0]")


def test_per_task_vectors_match_aggregates():
    # Task 0 peaks after task 1, then collapses — forgetting is measured
    # against the best intermediate, never just the diagonal.
    r = [[0.5], [0.9, 0.9], [0.1, 0.9, 0.9]]
    assert accuracy_per_task(r) == [0.1, 0.9, 0.9]
    assert forgetting_per_task(r) == [0.8, 0.0, 0.0]
    assert_close(forgetting(r), (0.8 + 0.0) / 2.0, "forgetting")
    b = backward_transfer_per_task(r)
    assert_close(b[0], 0.1 - 0.5, "bwt[0]")
    assert b[1] == 0.0 and b[2] == 0.0
    assert_close(backward_transfer(r), (b[0] + b[1]) / 2.0, "bwt")
    ret = retention_per_task(r)
    assert_close(ret[0], 0.1 / 0.9, "retention[0]")
    assert ret[1] == 1.0 and ret[2] == 1.0


def test_single_task_degenerate():
    r = [[0.7]]
    assert accuracy_per_task(r) == [0.7]
    assert forgetting_per_task(r) == [0.0]
    assert backward_transfer_per_task(r) == [0.0]
    assert retention_per_task(r) == [1.0]
    assert forgetting(r) == 0.0 and backward_transfer(r) == 0.0
    assert_close(final_average(r), 0.7, "final_average")


def test_all_zero_retention_is_one():
    # A task that never learned anything has nothing to forget:
    # retention 1.0 by definition, never 0/0.
    r = [[0.0], [0.0, 0.0]]
    assert retention_per_task(r) == [1.0, 1.0]
    assert forgetting_per_task(r) == [0.0, 0.0]


def test_splitmix64_is_the_rust_splitmix64():
    # Reference values of the standard splitmix64 stream — the same
    # constants the Rust side hard-codes.
    assert splitmix64(0) == 0xE220A8397B1DCDAF
    assert splitmix64(1) == 0x910A2DEC89025CC1
    # Deterministic and 64-bit clean.
    for s in (0, 1, 42, MASK):
        assert splitmix64(s) == splitmix64(s)
        assert 0 <= splitmix64(s) <= MASK


def test_partition_is_disjoint_exhaustive_and_seeded():
    for num_classes, num_tasks in [(10, 3), (8, 8), (4, 1), (7, 2)]:
        for seed in (3, 11, 99):
            parts = task_class_partition(num_classes, num_tasks, seed)
            assert parts == task_class_partition(num_classes, num_tasks, seed)
            flat = sorted(c for p in parts for c in p)
            assert flat == list(range(num_classes)), "not a partition"
            sizes = [len(p) for p in parts]
            assert max(sizes) - min(sizes) <= 1, "not near-equal"
            # The first num_classes % num_tasks tasks take the extra.
            base, extra = divmod(num_classes, num_tasks)
            assert sizes == [base + (1 if t < extra else 0)
                             for t in range(num_tasks)]
    # Different seeds give different shuffles (for a space this large).
    assert task_class_partition(10, 3, 3) != task_class_partition(10, 3, 4)


def test_schedules_are_deterministic_and_cover_tasks():
    n, k, seed = 96, 3, 7
    for schedule in ("roundrobin", "blocked", "random"):
        a = [task_for(schedule, i, n, k, seed) for i in range(n)]
        b = [task_for(schedule, i, n, k, seed) for i in range(n)]
        assert a == b, f"{schedule} is not deterministic"
        assert all(0 <= t < k for t in a)
        assert sorted(set(a)) == list(range(k)), f"{schedule} skipped a task"
    # Roundrobin is literally i % k; blocked is monotone contiguous.
    assert [task_for("roundrobin", i, n, k, seed) for i in range(6)] == \
        [0, 1, 2, 0, 1, 2]
    blocked = [task_for("blocked", i, n, k, seed) for i in range(n)]
    assert blocked == sorted(blocked)
    assert blocked.count(0) == blocked.count(1) == blocked.count(2) == n // k
    # Random depends on the seed, and positions are addressable out of
    # order (pure in i).
    r7 = [task_for("random", i, n, k, 7) for i in range(n)]
    r8 = [task_for("random", i, n, k, 8) for i in range(n)]
    assert r7 != r8
    assert task_for("random", 50, n, k, 7) == r7[50]


def main():
    tests = [(n, f) for n, f in sorted(globals().items())
             if n.startswith("test_") and callable(f)]
    for name, fn in tests:
        fn()
        print(f"  ok {name}")
    print(f"test_retention: {len(tests)} passed")


if __name__ == "__main__":
    main()
